//! Transfer Task Interceptor (§3.2): the CUDA memory-copy API boundary.
//!
//! The interceptor hooks `cudaMemcpy`/`cudaMemcpyAsync` (LD_PRELOAD in the
//! paper; the [`super::driver::SimWorld`] copy API here) *before* CUDA
//! binds the copy to the target GPU's PCIe path. It records the payload as
//! a Transfer Task and decides the route:
//!
//! * large host↔device copies → the Multipath Transfer Engine, with a
//!   Dummy Task replacing the stream-visible copy for async submissions;
//! * copies below the fallback threshold → native single-path DMA (the
//!   threshold also filters small control messages);
//! * GPU↔GPU copies and collective traffic are never intercepted (they use
//!   separate code paths: P2P DMA / kernel collectives).
//!
//! Whether a policy wants copies in the engine at all is the policy's own
//! call ([`crate::policy::PolicySpec::engine_eligible`]) — the native
//! baseline's defining property is precisely *not* being intercepted.

use super::transfer_task::TransferDesc;
use super::MmaConfig;

/// Routing decision for one intercepted copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Hand to the Multipath Transfer Engine (Dummy Task for async).
    Engine,
    /// Native single-path `cudaMemcpyAsync` semantics.
    Native,
}

/// Decide how to route an intercepted host↔device copy.
pub fn route(cfg: &MmaConfig, desc: &TransferDesc) -> Route {
    if desc.peer.is_some() {
        // GPU↔GPU copies are never intercepted (§3.2): they ride the
        // NVSwitch fabric as native P2P DMA regardless of size or policy.
        Route::Native
    } else if !cfg.policy.engine_eligible() || desc.bytes < cfg.fallback_threshold {
        Route::Native
    } else {
        Route::Engine
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::PolicySpec;
    use crate::topology::{Direction, GpuId, NumaId};

    fn desc(bytes: u64) -> TransferDesc {
        TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), bytes)
    }

    #[test]
    fn threshold_splits_routing() {
        let cfg = MmaConfig::default(); // threshold 11.3 MB
        assert_eq!(route(&cfg, &desc(1_000)), Route::Native);
        assert_eq!(route(&cfg, &desc(11_299_999)), Route::Native);
        assert_eq!(route(&cfg, &desc(11_300_000)), Route::Engine);
        assert_eq!(route(&cfg, &desc(8 << 30)), Route::Engine);
    }

    #[test]
    fn native_policy_always_native() {
        let cfg = MmaConfig::native();
        assert_eq!(route(&cfg, &desc(8 << 30)), Route::Native);
    }

    #[test]
    fn no_fallback_sends_everything_to_engine() {
        let cfg = MmaConfig::default().no_fallback();
        assert_eq!(route(&cfg, &desc(1)), Route::Engine);
    }

    #[test]
    fn peer_copies_are_never_intercepted() {
        // GPU↔GPU traffic has its own path (§3.2): even a huge peer copy
        // under an engine-eligible policy stays native.
        let cfg = MmaConfig::default().no_fallback();
        let d = TransferDesc::p2p(GpuId(0), GpuId(1), 8 << 30);
        assert_eq!(route(&cfg, &d), Route::Native);
    }

    #[test]
    fn every_engine_policy_respects_threshold() {
        for policy in [
            PolicySpec::MmaGreedy,
            PolicySpec::Static(vec![(GpuId(0), 1.0)]),
            PolicySpec::congestion_feedback(),
            PolicySpec::numa_aware(),
        ] {
            let cfg = MmaConfig::with_policy(policy);
            assert_eq!(route(&cfg, &desc(1_000)), Route::Native);
            assert_eq!(route(&cfg, &desc(100_000_000)), Route::Engine);
        }
    }
}
