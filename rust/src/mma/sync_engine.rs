//! Sync Engine (§3.3): keeps the Dummy Task's lifecycle synchronized with
//! the real multipath transfer.
//!
//! The Dummy Task is not a new CUDA primitive — it is two stream-ordered
//! operations:
//!
//! 1. a **host callback** that notifies the CPU the original copy point is
//!    active (stream→CPU direction), and
//! 2. a **spin kernel** polling a mapped pinned-host flag with `__ldcg` +
//!    `__nanosleep`, blocking the stream until the CPU confirms all
//!    micro-tasks landed (CPU→stream direction).
//!
//! `cudaDeviceSynchronize`, plain host callbacks, or CPU-side polling each
//! fail one direction of this handshake (§3.3); the paper's bidirectional
//! construction is reproduced exactly on [`crate::gpusim`]'s semantics.

use crate::gpusim::{CbId, FlagId, GpuSim, StreamId, StreamTask, TransferId};
use crate::topology::GpuId;

/// Dummy-task bookkeeping: callback registry + flag bindings.
pub struct SyncEngine {
    /// cb index → transfer whose copy point it marks.
    callbacks: Vec<TransferId>,
    /// transfer-indexed flag binding (sparse).
    flags: Vec<Option<FlagId>>,
}

impl Default for SyncEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl SyncEngine {
    /// Empty sync engine.
    pub fn new() -> SyncEngine {
        SyncEngine {
            callbacks: Vec::new(),
            flags: Vec::new(),
        }
    }

    /// Install the Dummy Task for `transfer` on `stream`: a host callback
    /// followed by a spin kernel on a fresh mapped flag. Returns the flag.
    pub fn install_dummy_task(
        &mut self,
        gpus: &mut GpuSim,
        dev: GpuId,
        stream: StreamId,
        transfer: TransferId,
    ) -> FlagId {
        let cb = CbId(self.callbacks.len() as u32);
        self.callbacks.push(transfer);
        let flag = gpus.alloc_flag();
        self.bind_flag(transfer, flag);
        gpus.enqueue(dev, stream, StreamTask::HostCallback { cb });
        gpus.enqueue(dev, stream, StreamTask::SpinKernel { flag });
        flag
    }

    /// Which transfer's copy point does this callback mark?
    pub fn transfer_of(&self, cb: CbId) -> TransferId {
        self.callbacks[cb.0 as usize]
    }

    /// Record the flag bound to a transfer.
    fn bind_flag(&mut self, t: TransferId, flag: FlagId) {
        let i = t.0 as usize;
        if self.flags.len() <= i {
            self.flags.resize(i + 1, None);
        }
        self.flags[i] = Some(flag);
    }

    /// Flag bound to a transfer, if async-intercepted.
    pub fn flag_of(&self, t: TransferId) -> Option<FlagId> {
        self.flags.get(t.0 as usize).copied().flatten()
    }

    /// All micro-tasks of `t` have landed: set the mapped flag
    /// (`*h_flag = 1`). Returns the streams whose spin kernels observe it;
    /// the driver releases each after one PCIe RTT.
    pub fn complete(&mut self, gpus: &mut GpuSim, t: TransferId) -> Vec<(GpuId, StreamId)> {
        let flag = self
            .flag_of(t)
            .expect("complete() on a transfer without a dummy task");
        gpus.set_flag(flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::Action;
    use crate::sim::Time;

    #[test]
    fn dummy_task_blocks_downstream_until_complete() {
        let mut gpus = GpuSim::new(1);
        let mut se = SyncEngine::new();
        let dev = GpuId(0);
        let s = gpus.create_stream(dev);
        let t = TransferId(9);
        se.install_dummy_task(&mut gpus, dev, s, t);
        // Downstream kernel that must not run before the transfer lands.
        gpus.enqueue(dev, s, StreamTask::Kernel { dur: Time::from_us(1), label: "down", tag: 0 });

        let actions = gpus.try_advance(Time::ZERO, dev, s);
        // Callback fires (copy point active), then the spin kernel parks.
        assert_eq!(actions.len(), 2, "{actions:?}");
        let Action::RunCallback { cb } = actions[0] else {
            panic!("expected callback first: {actions:?}");
        };
        assert_eq!(se.transfer_of(cb), t);
        assert!(matches!(actions[1], Action::SpinParked { .. }));

        // Transfer completes → flag set → stream releasable.
        let waiters = se.complete(&mut gpus, t);
        assert_eq!(waiters, vec![(dev, s)]);
        gpus.release_spin(dev, s);
        let actions = gpus.try_advance(Time::from_us(5), dev, s);
        assert!(matches!(actions[..], [Action::KernelStarted { .. }]));
    }

    #[test]
    fn separate_transfers_get_separate_flags() {
        let mut gpus = GpuSim::new(2);
        let mut se = SyncEngine::new();
        let s0 = gpus.create_stream(GpuId(0));
        let s1 = gpus.create_stream(GpuId(1));
        let f0 = se.install_dummy_task(&mut gpus, GpuId(0), s0, TransferId(0));
        let f1 = se.install_dummy_task(&mut gpus, GpuId(1), s1, TransferId(1));
        assert_ne!(f0, f1);
        assert_eq!(se.flag_of(TransferId(0)), Some(f0));
        assert_eq!(se.flag_of(TransferId(1)), Some(f1));
        gpus.try_advance(Time::ZERO, GpuId(0), s0);
        gpus.try_advance(Time::ZERO, GpuId(1), s1);
        // Completing transfer 1 must not release stream 0.
        let w = se.complete(&mut gpus, TransferId(1));
        assert_eq!(w, vec![(GpuId(1), s1)]);
    }

    #[test]
    fn completion_before_spin_parked_is_safe() {
        // If the engine finishes before the stream even reaches the spin
        // kernel (tiny transfer, long upstream kernel), the spin kernel
        // must pass straight through the already-set flag.
        let mut gpus = GpuSim::new(1);
        let mut se = SyncEngine::new();
        let dev = GpuId(0);
        let s = gpus.create_stream(dev);
        // Upstream kernel delays the stream.
        gpus.enqueue(dev, s, StreamTask::Kernel { dur: Time::from_ms(1), label: "up", tag: 0 });
        let t = TransferId(3);
        se.install_dummy_task(&mut gpus, dev, s, t);
        let a = gpus.try_advance(Time::ZERO, dev, s);
        assert!(matches!(a[..], [Action::KernelStarted { .. }]));
        // Engine completes while the kernel still runs (e.g. sync-path use).
        let waiters = se.complete(&mut gpus, t);
        assert!(waiters.is_empty());
        // Kernel finishes; callback + spin kernel both pass through.
        gpus.complete_head(dev, s);
        let a = gpus.try_advance(Time::from_ms(1), dev, s);
        assert_eq!(a.len(), 1, "{a:?}"); // just the callback
        assert!(matches!(a[0], Action::RunCallback { .. }));
        assert!(gpus.stream_idle(dev, s));
    }
}
