//! MMA — the paper's system: Transfer Task Interceptor (§3.2), Sync Engine
//! (§3.3), and Multipath Transfer Engine (§3.4), composed over the
//! simulated fabric by [`driver::SimWorld`].
//!
//! The module layout mirrors Figure 4/5 of the paper:
//!
//! * [`transfer_task`] — the recorded payload of an intercepted copy.
//! * [`interceptor`] — the CUDA-API boundary hook + fallback threshold.
//! * [`sync_engine`] — Dummy Task lifecycle (host callback + spin kernel).
//! * [`task_manager`] — chunking into micro-tasks, destination-tagged queue.
//! * [`engine`] — per-direction engine instances, worker actors, the Task
//!   Launcher's direct/relay dispatch and dual-pipeline relay.
//! * [`driver`] — the composed simulation world and its event loop.
//! * [`stats`] — per-engine counters, CPU-time accounting (Fig 11).
//!
//! Chunk→path *placement* is not decided here: the engine delegates it to
//! a pluggable [`crate::policy::TransferPolicy`] selected by
//! [`MmaConfig::policy`]. The paper's pull-based greedy selector (§3.4.2)
//! is one implementation ([`crate::policy::MmaGreedy`]); the native and
//! static-split baselines and the adaptive strategies are others.

pub mod driver;
pub mod engine;
pub mod interceptor;
pub mod stats;
pub mod sync_engine;
pub mod task_manager;
pub mod transfer_task;

pub use driver::{Notice, SimWorld, StreamHandle};
pub use engine::{ActionSink, Engine, EngineAction};
pub use transfer_task::{TransferClass, TransferDesc, NUM_CLASSES};

use crate::policy::PolicySpec;
use crate::topology::GpuId;

/// Default per-class share weights applied when QoS is enabled, indexed by
/// [`TransferClass::id`]: latency-critical 8, interactive 4, bulk 1,
/// background 0.5.
pub const DEFAULT_QOS_WEIGHTS: [f64; NUM_CLASSES] = [8.0, 4.0, 1.0, 0.5];

/// QoS transfer-class configuration (the `[qos]` TOML section /
/// `mma serve --qos on|off`).
///
/// Disabled (the default), every class weighs 1.0 and nothing is capped —
/// the fabric degenerates to classic unweighted max-min and the engine to
/// FIFO issue order, reproducing pre-QoS behavior exactly. Enabled, each
/// [`TransferClass`] carries its share weight on every link it crosses,
/// bulk-band flows may additionally be rate-capped, and the engine issues
/// latency-critical chunks ahead of bulk ones.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QosConfig {
    /// Master switch. Off = the degenerate unweighted/FIFO case.
    pub enabled: bool,
    /// Per-class share weights, indexed by [`TransferClass::id`].
    pub weights: [f64; NUM_CLASSES],
    /// Per-flow rate ceiling (bytes/sec) applied to bulk-band classes
    /// (`Bulk`, `Background`) while QoS is on; `INFINITY` = uncapped.
    pub bulk_cap_bps: f64,
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            enabled: false,
            weights: DEFAULT_QOS_WEIGHTS,
            bulk_cap_bps: f64::INFINITY,
        }
    }
}

impl QosConfig {
    /// QoS enabled at the default weights, no bulk cap.
    pub fn on() -> QosConfig {
        QosConfig {
            enabled: true,
            ..Default::default()
        }
    }

    /// QoS disabled (the degenerate unweighted case).
    pub fn off() -> QosConfig {
        QosConfig::default()
    }

    /// Fabric share weight for a class (1.0 while disabled).
    pub fn weight(&self, class: TransferClass) -> f64 {
        if self.enabled {
            self.weights[class as usize]
        } else {
            1.0
        }
    }

    /// Per-flow rate cap for a class (`INFINITY` unless QoS is on and the
    /// class sits in the bulk band).
    pub fn cap(&self, class: TransferClass) -> f64 {
        if self.enabled && class.is_bulk_band() {
            self.bulk_cap_bps
        } else {
            f64::INFINITY
        }
    }

    /// Validate at config-load time (same stance as
    /// [`PolicySpec::validate`]: a section that parses must not panic when
    /// the world is built).
    pub fn validate(&self) -> Result<(), String> {
        for (c, w) in TransferClass::ALL.iter().zip(self.weights) {
            if !(w.is_finite() && w > 0.0) {
                return Err(format!("{} weight {w} must be positive and finite", c.name()));
            }
        }
        if !(self.bulk_cap_bps > 0.0) {
            return Err(format!("bulk cap {} must be positive", self.bulk_cap_bps));
        }
        Ok(())
    }
}

/// Runtime tunables of MMA (all exposed as env vars in the paper's
/// implementation; here via [`crate::config`] / CLI).
#[derive(Clone, Debug)]
pub struct MmaConfig {
    /// Transfer policy deciding chunk→path placement (see
    /// [`crate::policy`]).
    pub policy: PolicySpec,
    /// Micro-task (chunk) size in bytes. Paper default: 5 MB (§3.4/§5.3).
    pub chunk_bytes: u64,
    /// Outstanding-queue depth per PCIe link. Paper sweet spot: 2 (§5.3).
    pub outstanding_depth: usize,
    /// Transfers below this fall back to native single-path copies (§3.2).
    /// Paper break-even: 11.3 MB H2D / 13 MB D2H at 5 MB chunks (§5.3).
    pub fallback_threshold: u64,
    /// Relay candidates; `None` = every peer GPU (NVML topology discovery).
    pub relay_gpus: Option<Vec<GpuId>>,
    /// Prefer micro-tasks destined to the queue's own GPU (§3.4.2).
    pub direct_priority: bool,
    /// Back off a path whose completions run late (contention, §3.4.2).
    pub contention_backoff: bool,
    /// Restrict relays to the target's NUMA node (§6, predictable-latency).
    pub numa_local_only: bool,
    /// Dual-pipeline relay (Fig 6); `false` = naive single pipeline.
    pub dual_pipeline: bool,
    /// Centralized dispatch mode: one transfer worker serves all GPUs (§4).
    pub centralized_dispatch: bool,
    /// Fixed engine activation overhead (callback → first dispatch), ns.
    pub activation_ns: u64,
    /// Observed/expected service-time ratio that marks a path contended.
    pub contention_beta: f64,
    /// QoS transfer-class weights/caps and the class-aware engine
    /// behavior switch (off by default: the degenerate unweighted case).
    pub qos: QosConfig,
    /// Incremental (connected-component) fabric rate allocation. `false`
    /// selects the reference full re-solve per flow event — simulation
    /// output is byte-identical either way (the replay determinism test
    /// pins this); the flag exists for benchmarking and as the oracle leg.
    pub incremental_alloc: bool,
    /// Timestamp-cascade solve coalescing: defer fabric rate recomputes
    /// within one virtual instant so a completion → replacement-chunk
    /// cascade settles under a single solve. `false` selects eager
    /// per-event solving — simulation output is byte-identical either
    /// way (property-tested); the flag exists for benchmarking and as
    /// the oracle leg.
    pub coalesce_solves: bool,
}

impl Default for MmaConfig {
    fn default() -> Self {
        MmaConfig {
            policy: PolicySpec::MmaGreedy,
            chunk_bytes: 5_000_000,
            outstanding_depth: 2,
            fallback_threshold: 11_300_000,
            relay_gpus: None,
            direct_priority: true,
            contention_backoff: true,
            numa_local_only: false,
            dual_pipeline: true,
            centralized_dispatch: false,
            activation_ns: 15_000,
            contention_beta: 2.5,
            qos: QosConfig::default(),
            incremental_alloc: true,
            coalesce_solves: true,
        }
    }
}

impl MmaConfig {
    /// Native-baseline configuration (everything bypasses the engine).
    pub fn native() -> MmaConfig {
        MmaConfig {
            policy: PolicySpec::Native,
            ..Default::default()
        }
    }

    /// Default configuration running the given policy (see
    /// [`MmaConfig::set_policy`] for the implications applied).
    pub fn with_policy(policy: PolicySpec) -> MmaConfig {
        let mut cfg = MmaConfig::default();
        cfg.set_policy(policy);
        cfg
    }

    /// Select `policy`, applying its configuration implications. Static
    /// splitting has no adaptive machinery (Fig 10's defining property),
    /// so choosing it by name disables contention backoff and direct
    /// priority — the same invariants [`crate::policy::static_split`]
    /// establishes. Every policy-selection surface (TOML `[policy]`,
    /// `MMA_POLICY`, `--policy`) funnels through here.
    pub fn set_policy(&mut self, policy: PolicySpec) {
        if matches!(policy, PolicySpec::Static(_)) {
            self.contention_backoff = false;
            self.direct_priority = false;
        }
        self.policy = policy;
    }

    /// MMA with an explicit relay set.
    pub fn with_relays(relays: Vec<GpuId>) -> MmaConfig {
        MmaConfig {
            relay_gpus: Some(relays),
            ..Default::default()
        }
    }

    /// Disable the small-transfer fallback (used by sweeps that need the
    /// engine exercised at every size, e.g. Fig 7/16).
    pub fn no_fallback(mut self) -> MmaConfig {
        self.fallback_threshold = 0;
        self
    }
}
