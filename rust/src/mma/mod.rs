//! MMA — the paper's system: Transfer Task Interceptor (§3.2), Sync Engine
//! (§3.3), and Multipath Transfer Engine (§3.4), composed over the
//! simulated fabric by [`driver::SimWorld`].
//!
//! The module layout mirrors Figure 4/5 of the paper:
//!
//! * [`transfer_task`] — the recorded payload of an intercepted copy.
//! * [`interceptor`] — the CUDA-API boundary hook + fallback threshold.
//! * [`sync_engine`] — Dummy Task lifecycle (host callback + spin kernel).
//! * [`task_manager`] — chunking into micro-tasks, destination-tagged queue.
//! * [`engine`] — per-direction engine instances, worker actors, the Task
//!   Launcher's direct/relay dispatch and dual-pipeline relay.
//! * [`driver`] — the composed simulation world and its event loop.
//! * [`stats`] — per-engine counters, CPU-time accounting (Fig 11).
//!
//! Chunk→path *placement* is not decided here: the engine delegates it to
//! a pluggable [`crate::policy::TransferPolicy`] selected by
//! [`MmaConfig::policy`]. The paper's pull-based greedy selector (§3.4.2)
//! is one implementation ([`crate::policy::MmaGreedy`]); the native and
//! static-split baselines and the adaptive strategies are others.

pub mod driver;
pub mod engine;
pub mod interceptor;
pub mod stats;
pub mod sync_engine;
pub mod task_manager;
pub mod transfer_task;

pub use driver::{Notice, SimWorld, StreamHandle};
pub use engine::Engine;
pub use transfer_task::{TransferClass, TransferDesc};

use crate::policy::PolicySpec;
use crate::topology::GpuId;

/// Runtime tunables of MMA (all exposed as env vars in the paper's
/// implementation; here via [`crate::config`] / CLI).
#[derive(Clone, Debug)]
pub struct MmaConfig {
    /// Transfer policy deciding chunk→path placement (see
    /// [`crate::policy`]).
    pub policy: PolicySpec,
    /// Micro-task (chunk) size in bytes. Paper default: 5 MB (§3.4/§5.3).
    pub chunk_bytes: u64,
    /// Outstanding-queue depth per PCIe link. Paper sweet spot: 2 (§5.3).
    pub outstanding_depth: usize,
    /// Transfers below this fall back to native single-path copies (§3.2).
    /// Paper break-even: 11.3 MB H2D / 13 MB D2H at 5 MB chunks (§5.3).
    pub fallback_threshold: u64,
    /// Relay candidates; `None` = every peer GPU (NVML topology discovery).
    pub relay_gpus: Option<Vec<GpuId>>,
    /// Prefer micro-tasks destined to the queue's own GPU (§3.4.2).
    pub direct_priority: bool,
    /// Back off a path whose completions run late (contention, §3.4.2).
    pub contention_backoff: bool,
    /// Restrict relays to the target's NUMA node (§6, predictable-latency).
    pub numa_local_only: bool,
    /// Dual-pipeline relay (Fig 6); `false` = naive single pipeline.
    pub dual_pipeline: bool,
    /// Centralized dispatch mode: one transfer worker serves all GPUs (§4).
    pub centralized_dispatch: bool,
    /// Fixed engine activation overhead (callback → first dispatch), ns.
    pub activation_ns: u64,
    /// Observed/expected service-time ratio that marks a path contended.
    pub contention_beta: f64,
}

impl Default for MmaConfig {
    fn default() -> Self {
        MmaConfig {
            policy: PolicySpec::MmaGreedy,
            chunk_bytes: 5_000_000,
            outstanding_depth: 2,
            fallback_threshold: 11_300_000,
            relay_gpus: None,
            direct_priority: true,
            contention_backoff: true,
            numa_local_only: false,
            dual_pipeline: true,
            centralized_dispatch: false,
            activation_ns: 15_000,
            contention_beta: 2.5,
        }
    }
}

impl MmaConfig {
    /// Native-baseline configuration (everything bypasses the engine).
    pub fn native() -> MmaConfig {
        MmaConfig {
            policy: PolicySpec::Native,
            ..Default::default()
        }
    }

    /// Default configuration running the given policy (see
    /// [`MmaConfig::set_policy`] for the implications applied).
    pub fn with_policy(policy: PolicySpec) -> MmaConfig {
        let mut cfg = MmaConfig::default();
        cfg.set_policy(policy);
        cfg
    }

    /// Select `policy`, applying its configuration implications. Static
    /// splitting has no adaptive machinery (Fig 10's defining property),
    /// so choosing it by name disables contention backoff and direct
    /// priority — the same invariants [`crate::policy::static_split`]
    /// establishes. Every policy-selection surface (TOML `[policy]`,
    /// `MMA_POLICY`, `--policy`) funnels through here.
    pub fn set_policy(&mut self, policy: PolicySpec) {
        if matches!(policy, PolicySpec::Static(_)) {
            self.contention_backoff = false;
            self.direct_priority = false;
        }
        self.policy = policy;
    }

    /// MMA with an explicit relay set.
    pub fn with_relays(relays: Vec<GpuId>) -> MmaConfig {
        MmaConfig {
            relay_gpus: Some(relays),
            ..Default::default()
        }
    }

    /// Disable the small-transfer fallback (used by sweeps that need the
    /// engine exercised at every size, e.g. Fig 7/16).
    pub fn no_fallback(mut self) -> MmaConfig {
        self.fallback_threshold = 0;
        self
    }
}
