//! Path Selector (§3.4.2): pull-based selection with outstanding-queue
//! backpressure as the implicit congestion signal.
//!
//! One *outstanding queue* exists per PCIe link (per direction), statically
//! bound to its GPU. The selector never pushes work to a path; a path
//! *pulls* a micro-task only when its outstanding queue has capacity. A
//! congested path retires slowly, stays full, and stops pulling — no
//! explicit link-state feedback needed.

use super::task_manager::{Chunk, TaskManager};
use super::{Mode, MmaConfig};
use crate::sim::Time;
use crate::topology::{GpuId, Topology};

/// Per-GPU pull decision outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Pulled {
    /// A direct micro-task (dest == this GPU).
    Direct(Chunk),
    /// A relay micro-task (this GPU forwards to `chunk.dest`).
    Relay(Chunk),
}

impl Pulled {
    /// The underlying chunk.
    pub fn chunk(&self) -> Chunk {
        match self {
            Pulled::Direct(c) | Pulled::Relay(c) => *c,
        }
    }
    /// Is this a relay pull?
    pub fn is_relay(&self) -> bool {
        matches!(self, Pulled::Relay(_))
    }
}

/// State of one outstanding queue (one per GPU per direction).
#[derive(Debug, Clone)]
pub struct OutstandingQueue {
    /// The GPU whose PCIe link this queue is bound to.
    pub gpu: GpuId,
    /// In-flight micro-task keys.
    pub slots: Vec<u64>,
    /// Depth limit.
    pub depth: usize,
    /// Contention detected on this path (backoff mode, §3.4.2).
    pub contended: bool,
    /// CPU "transfer thread" is busy dispatching until this time.
    pub busy_until: Time,
}

impl OutstandingQueue {
    /// New queue with the configured depth.
    pub fn new(gpu: GpuId, depth: usize) -> OutstandingQueue {
        OutstandingQueue {
            gpu,
            slots: Vec::with_capacity(depth),
            depth,
            contended: false,
            busy_until: Time::ZERO,
        }
    }

    /// Effective capacity: a contended queue backs off to depth 1, yielding
    /// bandwidth to latency-sensitive co-running traffic.
    pub fn effective_depth(&self, backoff_enabled: bool) -> usize {
        if backoff_enabled && self.contended {
            1
        } else {
            self.depth
        }
    }

    /// Can this queue pull more work?
    pub fn has_capacity(&self, backoff_enabled: bool) -> bool {
        self.slots.len() < self.effective_depth(backoff_enabled)
    }

    /// Occupy a slot with a chunk key.
    pub fn occupy(&mut self, key: u64) {
        debug_assert!(self.slots.len() < self.depth);
        self.slots.push(key);
    }

    /// Retire a chunk key; returns true if it was present.
    pub fn retire(&mut self, key: u64) -> bool {
        if let Some(p) = self.slots.iter().position(|&k| k == key) {
            self.slots.swap_remove(p);
            true
        } else {
            false
        }
    }
}

/// The pull policy. Stateless over [`TaskManager`] + [`OutstandingQueue`]s;
/// owned by the engine which carries the state.
pub struct PathSelector;

impl PathSelector {
    /// Decide the next micro-task for `gpu`'s outstanding queue, honoring:
    ///
    /// 1. **Direct-path-first** (if `direct_priority`): own-destination
    ///    micro-tasks before any relay work, minimizing NVLink spend.
    /// 2. **Longest-remaining-destination stealing**: relay work comes from
    ///    the destination with the most pending bytes.
    /// 3. **Relay eligibility**: this GPU must be in the relay set, and
    ///    NUMA restrictions respected.
    ///
    /// In static mode, only the pre-assigned queue for `gpu` is consulted.
    pub fn pull(
        tm: &mut TaskManager,
        topo: &Topology,
        cfg: &MmaConfig,
        gpu: GpuId,
    ) -> Option<Pulled> {
        match &cfg.mode {
            Mode::Static(_) => {
                let c = tm.pop_assigned(gpu)?;
                if c.dest == gpu {
                    Some(Pulled::Direct(c))
                } else {
                    Some(Pulled::Relay(c))
                }
            }
            Mode::Native => None,
            Mode::Mma => {
                if cfg.direct_priority {
                    if let Some(c) = tm.pop_direct(gpu) {
                        return Some(Pulled::Direct(c));
                    }
                }
                let relay_ok = Self::in_relay_set(cfg, gpu);
                if relay_ok {
                    let steal = tm.pop_steal(gpu, |dest| {
                        !cfg.numa_local_only || topo.numa_of(dest) == topo.numa_of(gpu)
                    });
                    if let Some(c) = steal {
                        return Some(Pulled::Relay(c));
                    }
                }
                if !cfg.direct_priority {
                    // Without direct priority the queue may still end up
                    // serving its own destination — but only after relay
                    // stealing was considered first (the Table 2 ablation).
                    if let Some(c) = tm.pop_direct(gpu) {
                        return Some(Pulled::Direct(c));
                    }
                }
                None
            }
        }
    }

    /// Is `gpu` allowed to relay?
    pub fn in_relay_set(cfg: &MmaConfig, gpu: GpuId) -> bool {
        match &cfg.relay_gpus {
            None => true,
            Some(set) => set.contains(&gpu),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::TransferId;
    use crate::topology::h20x8;

    fn mgr_with(dest: GpuId, bytes: u64) -> TaskManager {
        let mut tm = TaskManager::new(8);
        tm.push_pending(&TaskManager::split(TransferId(1), dest, bytes, 5_000_000));
        tm
    }

    #[test]
    fn direct_priority_wins_over_steal() {
        let topo = h20x8();
        let cfg = MmaConfig::default();
        let mut tm = TaskManager::new(8);
        tm.push_pending(&TaskManager::split(TransferId(1), GpuId(0), 10_000_000, 5_000_000));
        tm.push_pending(&TaskManager::split(TransferId(2), GpuId(1), 50_000_000, 5_000_000));
        // GPU 0 has own work → direct, even though dest 1 has more bytes.
        let p = PathSelector::pull(&mut tm, &topo, &cfg, GpuId(0)).unwrap();
        assert_eq!(p, Pulled::Direct(Chunk {
            transfer: TransferId(1),
            index: 0,
            bytes: 5_000_000,
            dest: GpuId(0),
        }));
    }

    #[test]
    fn without_direct_priority_steal_comes_first() {
        let topo = h20x8();
        let cfg = MmaConfig {
            direct_priority: false,
            ..Default::default()
        };
        let mut tm = TaskManager::new(8);
        tm.push_pending(&TaskManager::split(TransferId(1), GpuId(0), 10_000_000, 5_000_000));
        tm.push_pending(&TaskManager::split(TransferId(2), GpuId(1), 50_000_000, 5_000_000));
        let p = PathSelector::pull(&mut tm, &topo, &cfg, GpuId(0)).unwrap();
        assert!(p.is_relay(), "{p:?}");
        assert_eq!(p.chunk().dest, GpuId(1));
    }

    #[test]
    fn relay_set_restriction() {
        let topo = h20x8();
        let cfg = MmaConfig::with_relays(vec![GpuId(2)]);
        let mut tm = mgr_with(GpuId(0), 50_000_000);
        // GPU 1 is not in the relay set: no pull.
        assert!(PathSelector::pull(&mut tm, &topo, &cfg, GpuId(1)).is_none());
        // GPU 2 is: relay pull.
        let p = PathSelector::pull(&mut tm, &topo, &cfg, GpuId(2)).unwrap();
        assert!(p.is_relay());
    }

    #[test]
    fn numa_local_only_blocks_cross_socket_relay() {
        let topo = h20x8();
        let cfg = MmaConfig {
            numa_local_only: true,
            ..Default::default()
        };
        let mut tm = mgr_with(GpuId(0), 50_000_000); // dest on numa0
        // GPU 5 lives on numa1 → not eligible.
        assert!(PathSelector::pull(&mut tm, &topo, &cfg, GpuId(5)).is_none());
        // GPU 1 (numa0) is eligible.
        assert!(PathSelector::pull(&mut tm, &topo, &cfg, GpuId(1)).is_some());
    }

    #[test]
    fn native_mode_never_pulls() {
        let topo = h20x8();
        let cfg = MmaConfig::native();
        let mut tm = mgr_with(GpuId(0), 50_000_000);
        assert!(PathSelector::pull(&mut tm, &topo, &cfg, GpuId(0)).is_none());
    }

    #[test]
    fn outstanding_queue_capacity_and_backoff() {
        let mut q = OutstandingQueue::new(GpuId(0), 2);
        assert!(q.has_capacity(true));
        q.occupy(1);
        q.occupy(2);
        assert!(!q.has_capacity(true));
        assert!(q.retire(1));
        assert!(!q.retire(1));
        assert!(q.has_capacity(true));
        // Contended queues back off to depth 1.
        q.contended = true;
        assert_eq!(q.effective_depth(true), 1);
        assert!(!q.has_capacity(true), "1 slot used, backoff depth 1");
        assert!(q.has_capacity(false), "backoff disabled → full depth");
    }
}
