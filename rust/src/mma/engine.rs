//! Multipath Transfer Engine (§3.4): per-direction engine instances that
//! split transfers into micro-tasks, pull them into per-link outstanding
//! queues, and launch direct/relay DMA — including the Task Launcher's
//! two-stage relay with dual-pipeline overlap (Fig 6).
//!
//! The engine is a passive state machine: the driver feeds it events
//! (`activate`, `on_wake`, `on_flow_done`, `on_retire`) and executes the
//! returned [`EngineAction`]s against the fabric and event queue. In the
//! paper these transitions run on per-GPU *transfer* and *synchronization*
//! threads; the virtual-time model preserves their scheduling behaviour
//! (dispatch serialization, `cudaEventSynchronize` wake-up latency) via
//! explicit latency terms, and accounts their CPU burn in
//! [`super::stats::EngineStats`].
//!
//! The per-event path is allocation-free at steady state: callers hand the
//! engine a reusable [`ActionSink`] (the `*_into` entry points) instead of
//! receiving a fresh `Vec<EngineAction>` per event, chunk bookkeeping
//! lives in a generational [`Slab`] keyed by dense 24-bit ids (which ride
//! in fabric flow tags) instead of hash maps, and link paths are inline
//! [`SmallPath`]s. The old `Vec`-returning methods remain as thin
//! wrappers.

use super::stats::EngineStats;
use super::task_manager::{Chunk, PullClassPolicy, TaskManager};
use super::transfer_task::{TransferClass, TransferDesc};
use super::MmaConfig;
use crate::gpusim::TransferId;
use crate::policy::{OutstandingQueue, PolicyView, Pulled, TransferPolicy};
use crate::sim::Time;
use crate::topology::{Direction, GpuId, NumaId, Topology};
use crate::util::fxmap::FxHashMap;
use crate::util::slab::Slab;
use crate::util::SmallPath;
use std::collections::VecDeque;

/// Chunk keys are slab keys and fit in 24 bits (they ride in the `b`
/// field of a fabric flow tag). Anything at or above this bound can never
/// name a live chunk.
const KEY_SPACE: u64 = 1 << 24;

/// What the driver must do on the engine's behalf.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineAction {
    /// Launch a DMA flow for a micro-task stage.
    StartFlow {
        /// In-flight chunk key (routes the completion back).
        key: u64,
        /// Links the flow traverses.
        path: SmallPath,
        /// Bytes.
        bytes: u64,
        /// Setup latency before the flow occupies bandwidth.
        latency: Time,
        /// QoS traffic class (fabric share weight + sampling channel).
        class: TransferClass,
        /// True when this stage delivers the chunk to its destination
        /// (direct, or the relay's forwarding hop). Bandwidth sampling
        /// counts only terminal stages, so relayed bytes aren't counted
        /// twice.
        terminal: bool,
    },
    /// Wake the worker for `gpu` at `at` (schedule `on_wake`).
    WakeAt {
        /// Worker's GPU.
        gpu: GpuId,
        /// When.
        at: Time,
    },
    /// The sync thread retires chunk `key` at `at` (schedule `on_retire`).
    RetireAt {
        /// Owning queue's GPU.
        gpu: GpuId,
        /// Chunk key.
        key: u64,
        /// When (delivery + `cudaEventSynchronize` wake-up).
        at: Time,
    },
    /// Every micro-task of `transfer` has landed and been retired.
    TransferComplete {
        /// The finished transfer.
        transfer: TransferId,
        /// Bytes that took the direct path.
        bytes_direct: u64,
        /// Bytes that took relay paths.
        bytes_relay: u64,
    },
}

/// Caller-owned, reusable buffer the engine's `*_into` entry points append
/// their [`EngineAction`]s to. Holding one sink for the lifetime of a
/// simulation (clear, feed, drain, repeat) makes the per-event path
/// allocation-free once the buffer has warmed up to the peak burst size;
/// the lifetime counters ([`ActionSink::pushed`] / [`ActionSink::grows`])
/// let the perf harness report actions-per-allocation and assert the
/// steady state stops growing.
#[derive(Debug, Default)]
pub struct ActionSink {
    actions: Vec<EngineAction>,
    pushed: u64,
    grows: u64,
}

impl ActionSink {
    /// Empty sink.
    pub fn new() -> ActionSink {
        ActionSink::default()
    }

    /// Append one action, counting buffer growth.
    pub fn push(&mut self, a: EngineAction) {
        if self.actions.len() == self.actions.capacity() {
            self.grows += 1;
        }
        self.pushed += 1;
        self.actions.push(a);
    }

    /// Append every action of an iterator.
    pub fn extend<I: IntoIterator<Item = EngineAction>>(&mut self, iter: I) {
        for a in iter {
            self.push(a);
        }
    }

    /// Drop buffered actions, keeping capacity.
    pub fn clear(&mut self) {
        self.actions.clear();
    }

    /// Move the buffered actions out, keeping capacity for reuse.
    pub fn drain(&mut self) -> std::vec::Drain<'_, EngineAction> {
        self.actions.drain(..)
    }

    /// Buffered actions.
    pub fn as_slice(&self) -> &[EngineAction] {
        &self.actions
    }

    /// Buffered action count.
    pub fn len(&self) -> usize {
        self.actions.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.actions.is_empty()
    }

    /// Consume the sink, returning its buffer (the legacy `Vec` API).
    pub fn into_vec(self) -> Vec<EngineAction> {
        self.actions
    }

    /// Lifetime count of actions pushed through this sink.
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Lifetime count of buffer reallocations (capacity growth events).
    /// Flat at steady state = the per-event path stopped allocating.
    pub fn grows(&self) -> u64 {
        self.grows
    }
}

#[derive(Debug, Clone)]
struct ActiveTransfer {
    desc: TransferDesc,
    total_chunks: u32,
    retired_chunks: u32,
    bytes_direct: u64,
    bytes_relay: u64,
}

#[derive(Debug, Clone, Copy)]
struct InFlight {
    chunk: Chunk,
    /// The PCIe-link GPU whose outstanding queue holds this chunk.
    path_gpu: GpuId,
    relay: bool,
    host_numa: NumaId,
    dispatched: Time,
    stage: u8,
    /// QoS class of the parent transfer (carried by the chunk; cached so
    /// retirement can update per-class queue counts without a lookup).
    class: TransferClass,
    /// Slab slot of the parent [`ActiveTransfer`] — retirement goes
    /// straight to the slot instead of hashing the transfer id.
    t_slot: u32,
    /// Uncontended expected service time (for contention inference),
    /// accounting for chunks queued ahead on the same lane at dispatch.
    expected_s: f64,
}

/// Which per-GPU DMA lane a stage occupies. Copies queued on the same lane
/// execute back-to-back (one copy engine per lane per direction), which is
/// what lets depth-2 outstanding queues pipeline without bubbles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LaneKind {
    /// The GPU's PCIe copy engine for this engine's direction.
    Pcie = 0,
    /// The GPU's P2P (NVLink) copy engine.
    Nv = 1,
}

/// A flow whose DMA descriptor is programmed but waiting behind the lane's
/// active copy.
#[derive(Debug, Clone)]
struct QueuedFlow {
    key: u64,
    path: SmallPath,
    bytes: u64,
    class: TransferClass,
    terminal: bool,
}

/// One GPU's pair of serializing DMA lanes.
#[derive(Debug, Default)]
struct Lanes {
    active: [Option<u64>; 2],
    waiting: [VecDeque<QueuedFlow>; 2],
}

impl Lanes {
    fn occupancy(&self, lane: LaneKind) -> usize {
        let i = lane as usize;
        self.active[i].is_some() as usize + self.waiting[i].len()
    }
}

/// One direction's Multipath Transfer Engine.
pub struct Engine {
    /// Engine index within the driver.
    pub id: u8,
    /// Direction this instance serves (H2D and D2H run separately, §4).
    pub dir: Direction,
    /// Tunables.
    pub cfg: MmaConfig,
    /// The pluggable chunk→path placement strategy (built from
    /// `cfg.policy`; each engine instance carries its own state).
    policy: Box<dyn TransferPolicy>,
    tm: TaskManager,
    queues: Vec<OutstandingQueue>,
    lanes: Vec<Lanes>,
    relay_inflight: Vec<u32>,
    /// In-flight chunks, keyed by dense generational slab ids (< 2^24).
    inflight: Slab<InFlight>,
    /// Live transfers (slab) plus the transfer-id → slot handle map used
    /// once per dispatched chunk; retirement uses the slot cached in
    /// [`InFlight::t_slot`].
    transfers: Slab<ActiveTransfer>,
    tmap: FxHashMap<u32, u32>,
    /// Reused buffer for [`TaskManager::split_into`] during activation.
    chunk_scratch: Vec<Chunk>,
    /// Counters (Fig 11 CPU accounting, relay/direct byte split).
    pub stats: EngineStats,
    central_busy_until: Time,
}

impl Engine {
    /// New engine over `gpu_count` PCIe links.
    pub fn new(id: u8, dir: Direction, cfg: MmaConfig, gpu_count: usize) -> Engine {
        Engine {
            id,
            dir,
            policy: cfg.policy.build(&cfg),
            tm: TaskManager::new(gpu_count),
            queues: (0..gpu_count)
                .map(|g| OutstandingQueue::new(GpuId(g as u8), cfg.outstanding_depth))
                .collect(),
            lanes: (0..gpu_count).map(|_| Lanes::default()).collect(),
            relay_inflight: vec![0; gpu_count],
            inflight: Slab::new(),
            transfers: Slab::new(),
            tmap: FxHashMap::default(),
            chunk_scratch: Vec::new(),
            stats: EngineStats::new(gpu_count),
            central_busy_until: Time::ZERO,
            cfg,
        }
    }

    /// Any work queued or in flight?
    pub fn is_idle(&self) -> bool {
        self.tm.is_empty() && self.inflight.is_empty()
    }

    /// The live placement policy (read-only: decision surfaces that sit
    /// outside the chunk→path hot loop, e.g. the serving layer's
    /// host-vs-peer fetch choice).
    pub fn policy(&self) -> &dyn TransferPolicy {
        &*self.policy
    }

    /// Number of live transfers.
    pub fn active_transfers(&self) -> usize {
        self.transfers.len()
    }

    /// In-flight chunk lookup guarded by the 24-bit key space.
    fn lookup(&self, key: u64) -> Option<&InFlight> {
        if key >= KEY_SPACE {
            return None;
        }
        self.inflight.get(key as u32)
    }

    /// The copy point of `transfer` is active (§3.1 step ②→③): split into
    /// micro-tasks, hand them to the policy, and wake the workers.
    /// (Legacy `Vec` wrapper over [`Engine::activate_into`].)
    pub fn activate(
        &mut self,
        now: Time,
        transfer: TransferId,
        desc: TransferDesc,
        topo: &Topology,
    ) -> Vec<EngineAction> {
        let mut sink = ActionSink::new();
        self.activate_into(now, transfer, desc, topo, &mut sink);
        sink.into_vec()
    }

    /// Allocation-free form of [`Engine::activate`]: actions land in `sink`.
    pub fn activate_into(
        &mut self,
        now: Time,
        transfer: TransferId,
        desc: TransferDesc,
        topo: &Topology,
        sink: &mut ActionSink,
    ) {
        let mut chunks = std::mem::take(&mut self.chunk_scratch);
        TaskManager::split_into(
            transfer,
            desc.gpu,
            desc.bytes,
            self.cfg.chunk_bytes,
            desc.class,
            &mut chunks,
        );
        let total = chunks.len() as u32;
        let t_slot = self.transfers.insert(ActiveTransfer {
            desc,
            total_chunks: total,
            retired_chunks: 0,
            bytes_direct: 0,
            bytes_relay: 0,
        });
        self.tmap.insert(transfer.0, t_slot);
        let view = PolicyView {
            topo,
            dir: self.dir,
            queues: &self.queues,
            now,
            class_pull: PullClassPolicy {
                by_class: self.cfg.qos.enabled,
                ..Default::default()
            },
            class_pending: self.tm.pending_by_class(),
        };
        self.policy.admit(&chunks, &mut self.tm, &view);
        self.chunk_scratch = chunks;
        // Wake every worker after the fixed activation overhead; workers
        // with no eligible work simply find nothing to pull.
        let at = now + Time::from_ns(self.cfg.activation_ns);
        for g in 0..self.queues.len() {
            sink.push(EngineAction::WakeAt {
                gpu: GpuId(g as u8),
                at,
            });
        }
    }

    /// Transfer-thread wake-up for `gpu`: pull micro-tasks while the
    /// outstanding queue has capacity, dispatching each (§3.4.2/§3.4.3).
    /// (Legacy `Vec` wrapper over [`Engine::on_wake_into`].)
    pub fn on_wake(&mut self, now: Time, gpu: GpuId, topo: &Topology) -> Vec<EngineAction> {
        let mut sink = ActionSink::new();
        self.on_wake_into(now, gpu, topo, &mut sink);
        sink.into_vec()
    }

    /// Allocation-free form of [`Engine::on_wake`]: actions land in `sink`.
    pub fn on_wake_into(&mut self, now: Time, gpu: GpuId, topo: &Topology, sink: &mut ActionSink) {
        loop {
            let gi = gpu.0 as usize;
            if !self.queues[gi].has_capacity(self.cfg.contention_backoff) {
                break;
            }
            // Naive single-pipeline relay (Fig 6a ablation): at most one
            // relay micro-task in flight per relay GPU.
            let relay_blocked = !self.cfg.dual_pipeline && self.relay_inflight[gi] > 0;
            let pulled = if relay_blocked && !self.tm.has_direct(gpu) {
                None
            } else {
                let view = PolicyView {
                    topo,
                    dir: self.dir,
                    queues: &self.queues,
                    now,
                    class_pull: self.class_pull(gi),
                    class_pending: self.tm.pending_by_class(),
                };
                self.policy.pull(&mut self.tm, gpu, &view)
            };
            let Some(pulled) = pulled else { break };
            self.dispatch_into(now, gpu, pulled, topo, sink);
        }
    }

    /// QoS class policy for one of `gpu`'s pull rounds. All-false while
    /// QoS is disabled (legacy FIFO). Enabled:
    ///
    /// * pops are class-prioritized (`by_class`);
    /// * a queue already holding a bulk-band chunk in flight pulls only
    ///   critical-band work while critical flows are live anywhere — the
    ///   outstanding-depth throttle that caps bulk at one slot under
    ///   contention with latency-critical traffic (`critical_only`);
    /// * a queue with an in-flight critical chunk refuses to steal
    ///   bulk-band work onto its path (`no_bulk_steal`; the guard itself
    ///   lives in [`TaskManager::pop_steal_scored`]).
    fn class_pull(&self, gi: usize) -> PullClassPolicy {
        if !self.cfg.qos.enabled {
            return PullClassPolicy::default();
        }
        let critical_live = self.tm.critical_pending() > 0
            || self.queues.iter().any(|q| q.critical_inflight > 0);
        PullClassPolicy {
            by_class: true,
            critical_only: critical_live && self.queues[gi].bulk_inflight > 0,
            no_bulk_steal: self.queues[gi].critical_inflight > 0,
        }
    }

    /// Dispatch one pulled micro-task through the Task Launcher.
    fn dispatch_into(
        &mut self,
        now: Time,
        gpu: GpuId,
        pulled: Pulled,
        topo: &Topology,
        sink: &mut ActionSink,
    ) {
        let chunk = pulled.chunk();
        let relay = pulled.is_relay();
        let gi = gpu.0 as usize;
        let t_slot = *self
            .tmap
            .get(&chunk.transfer.0)
            .expect("chunk for unknown transfer");
        let host_numa = self
            .transfers
            .get(t_slot)
            .expect("chunk for unknown transfer")
            .desc
            .host_numa;
        let class = chunk.class;

        // Transfer-thread dispatch serialization: the (per-GPU or central)
        // worker burns `dispatch_cpu_ns` per micro-task.
        let lat = topo.lat;
        let busy = if self.cfg.centralized_dispatch {
            &mut self.central_busy_until
        } else {
            &mut self.queues[gi].busy_until
        };
        let start = (*busy).max(now) + Time::from_ns(lat.dispatch_cpu_ns);
        *busy = start;
        let cpu_wait = start.since(now);

        // Stage-1 path + lane (§3.4.3 Task Launcher).
        let (path, setup, lane) = match (self.dir, relay) {
            (Direction::H2D, false) => (
                topo.h2d_direct(host_numa, chunk.dest),
                lat.dma_setup_ns,
                LaneKind::Pcie,
            ),
            (Direction::H2D, true) => (
                topo.h2d_relay_stage1(host_numa, gpu),
                lat.dma_setup_ns,
                LaneKind::Pcie,
            ),
            (Direction::D2H, false) => (
                topo.d2h_direct(chunk.dest, host_numa),
                lat.dma_setup_ns,
                LaneKind::Pcie,
            ),
            (Direction::D2H, true) => (
                topo.d2h_relay_stage1(chunk.dest, gpu),
                lat.p2p_setup_ns,
                LaneKind::Nv,
            ),
        };
        let ahead = self.lanes[gi].occupancy(lane);
        let expected_s =
            self.expected_service_secs(chunk.bytes, relay, gpu, topo) * (ahead as f64 + 1.0);
        let key = self.inflight.insert(InFlight {
            chunk,
            path_gpu: gpu,
            relay,
            host_numa,
            dispatched: now,
            stage: 1,
            class,
            t_slot,
            expected_s,
        }) as u64;
        if self.queues[gi].slots.is_empty() {
            self.stats.queue_busy(gpu, now);
        }
        self.queues[gi].occupy(key, class);
        if relay {
            self.relay_inflight[gi] += 1;
        }
        self.stats
            .dispatched(gpu, chunk.bytes, relay, lat.dispatch_cpu_ns);

        let launched = self.lane_submit(
            gpu,
            lane,
            QueuedFlow {
                key,
                path,
                bytes: chunk.bytes,
                class,
                terminal: !relay,
            },
            cpu_wait + Time::from_ns(setup),
        );
        sink.extend(launched);
    }

    /// Submit a stage's flow to a serializing DMA lane. If the lane is
    /// busy, the descriptor queues behind the active copy and launches
    /// back-to-back when it finishes (returns no action yet). Under QoS,
    /// waiting descriptors are ordered by class priority (FIFO within a
    /// class): a latency-critical chunk issues before queued bulk ones.
    fn lane_submit(
        &mut self,
        gpu: GpuId,
        lane: LaneKind,
        flow: QueuedFlow,
        cold_latency: Time,
    ) -> Option<EngineAction> {
        let by_class = self.cfg.qos.enabled;
        let li = lane as usize;
        let lanes = &mut self.lanes[gpu.0 as usize];
        if lanes.active[li].is_none() {
            lanes.active[li] = Some(flow.key);
            Some(EngineAction::StartFlow {
                key: flow.key,
                path: flow.path,
                bytes: flow.bytes,
                latency: cold_latency,
                class: flow.class,
                terminal: flow.terminal,
            })
        } else {
            let w = &mut lanes.waiting[li];
            let pos = if by_class {
                w.iter().position(|q| q.class > flow.class).unwrap_or(w.len())
            } else {
                w.len()
            };
            w.insert(pos, flow);
            None
        }
    }

    /// A lane's active copy finished: hand the lane to the next queued
    /// descriptor (warm turnaround).
    fn lane_release(
        &mut self,
        gpu: GpuId,
        lane: LaneKind,
        key: u64,
        topo: &Topology,
    ) -> Option<EngineAction> {
        let li = lane as usize;
        let lanes = &mut self.lanes[gpu.0 as usize];
        debug_assert_eq!(lanes.active[li], Some(key), "lane released by non-owner");
        lanes.active[li] = None;
        let next = lanes.waiting[li].pop_front()?;
        lanes.active[li] = Some(next.key);
        Some(EngineAction::StartFlow {
            key: next.key,
            path: next.path,
            bytes: next.bytes,
            latency: Time::from_ns(topo.lat.dma_turnaround_ns),
            class: next.class,
            terminal: next.terminal,
        })
    }

    /// Lane used by a chunk's current stage.
    fn lane_of(&self, inf: &InFlight) -> LaneKind {
        match (self.dir, inf.relay, inf.stage) {
            (_, false, _) => LaneKind::Pcie,
            (Direction::H2D, true, 1) => LaneKind::Pcie,
            (Direction::H2D, true, _) => LaneKind::Nv,
            (Direction::D2H, true, 1) => LaneKind::Nv,
            (Direction::D2H, true, _) => LaneKind::Pcie,
        }
    }

    /// A micro-task stage's DMA finished.
    /// (Legacy `Vec` wrapper over [`Engine::on_flow_done_into`].)
    pub fn on_flow_done(&mut self, now: Time, key: u64, topo: &Topology) -> Vec<EngineAction> {
        let mut sink = ActionSink::new();
        self.on_flow_done_into(now, key, topo, &mut sink);
        sink.into_vec()
    }

    /// Allocation-free form of [`Engine::on_flow_done`].
    ///
    /// A completion notice for a key the engine does not know (stale,
    /// duplicated, or corrupted) is counted in
    /// [`EngineStats::stray_events`] and skipped instead of aborting the
    /// replay.
    pub fn on_flow_done_into(&mut self, now: Time, key: u64, topo: &Topology, sink: &mut ActionSink) {
        let Some(inf) = self.lookup(key).copied() else {
            self.stats.stray_events += 1;
            return;
        };
        let lat = topo.lat;
        // Free the lane this stage occupied; the next queued descriptor
        // launches back-to-back.
        let done_lane = self.lane_of(&inf);
        sink.extend(self.lane_release(inf.path_gpu, done_lane, key, topo));

        if inf.relay && inf.stage == 1 {
            // Launch stage 2: the forwarding hop. Explicit stream
            // dependencies order the two stages (§3.4.3); the dual-pipeline
            // overlap comes from the second outstanding slot running its
            // stage 1 on the other lane concurrently (Fig 6b).
            let (path, setup, lane) = match self.dir {
                Direction::H2D => (
                    topo.h2d_relay_stage2(inf.path_gpu, inf.chunk.dest),
                    lat.p2p_setup_ns,
                    LaneKind::Nv,
                ),
                Direction::D2H => (
                    topo.d2h_relay_stage2(inf.path_gpu, inf.host_numa),
                    lat.dma_setup_ns,
                    LaneKind::Pcie,
                ),
            };
            self.inflight.get_mut(key as u32).expect("stage lookup").stage = 2;
            let launched = self.lane_submit(
                inf.path_gpu,
                lane,
                QueuedFlow {
                    key,
                    path,
                    bytes: inf.chunk.bytes,
                    class: inf.class,
                    terminal: true,
                },
                Time::from_ns(setup),
            );
            sink.extend(launched);
            return;
        }
        // Delivered: the sync thread observes completion after the
        // cudaEventSynchronize wake-up latency, then retires the slot.
        sink.push(EngineAction::RetireAt {
            gpu: inf.path_gpu,
            key,
            at: now + Time::from_ns(lat.event_sync_ns),
        });
    }

    /// Sync-thread retirement of a chunk: free the slot, detect contention,
    /// account transfer progress, and pull more work.
    /// (Legacy `Vec` wrapper over [`Engine::on_retire_into`].)
    pub fn on_retire(
        &mut self,
        now: Time,
        gpu: GpuId,
        key: u64,
        topo: &Topology,
    ) -> Vec<EngineAction> {
        let mut sink = ActionSink::new();
        self.on_retire_into(now, gpu, key, topo, &mut sink);
        sink.into_vec()
    }

    /// Allocation-free form of [`Engine::on_retire`].
    ///
    /// A retirement notice for an unknown or already-retired key is
    /// counted in [`EngineStats::stray_events`] and skipped — a stray
    /// completion cannot abort a whole replay.
    pub fn on_retire_into(
        &mut self,
        now: Time,
        gpu: GpuId,
        key: u64,
        topo: &Topology,
        sink: &mut ActionSink,
    ) {
        let inf = if key < KEY_SPACE {
            self.inflight.remove(key as u32)
        } else {
            None
        };
        let Some(inf) = inf else {
            self.stats.stray_events += 1;
            return;
        };
        debug_assert_eq!(inf.path_gpu, gpu);
        let gi = gpu.0 as usize;
        let retired = self.queues[gi].retire(key, inf.class);
        debug_assert!(retired);
        if inf.relay {
            self.relay_inflight[gi] -= 1;
        }
        if self.queues[gi].slots.is_empty() {
            self.stats.queue_idle(gpu, now);
        }

        // Feed the completion back to the policy (its congestion signal).
        let observed = now.since(inf.dispatched).as_secs_f64();
        self.policy
            .on_completion(gpu, inf.chunk.bytes, inf.relay, observed, inf.expected_s);

        // Contention inference (§3.4.2): completion far beyond the
        // uncontended expectation marks the path contended; a clean
        // completion clears it.
        if self.cfg.contention_backoff {
            let was = self.queues[gi].contended;
            self.queues[gi].contended = observed > self.cfg.contention_beta * inf.expected_s;
            if self.queues[gi].contended && !was {
                self.stats.backoff_events[gi] += 1;
            }
        }

        // Transfer progress (straight to the slot cached at dispatch).
        let done = match self.transfers.get_mut(inf.t_slot) {
            Some(t) => {
                t.retired_chunks += 1;
                if inf.relay {
                    t.bytes_relay += inf.chunk.bytes;
                } else {
                    t.bytes_direct += inf.chunk.bytes;
                }
                t.retired_chunks == t.total_chunks
            }
            None => {
                self.stats.stray_events += 1;
                false
            }
        };
        if done {
            let t = self
                .transfers
                .remove(inf.t_slot)
                .expect("transfer slot vanished");
            self.tmap.remove(&inf.chunk.transfer.0);
            self.stats.transfers_completed += 1;
            sink.push(EngineAction::TransferComplete {
                transfer: inf.chunk.transfer,
                bytes_direct: t.bytes_direct,
                bytes_relay: t.bytes_relay,
            });
        }
        // Freed a slot: pull again immediately. Inlined rather than
        // emitting `WakeAt {now}` — saves one event-queue round trip per
        // retired chunk (see EXPERIMENTS.md §Perf).
        self.on_wake_into(now, gpu, topo, sink);
    }

    /// Uncontended expected service time for one micro-task (seconds).
    fn expected_service_secs(&self, bytes: u64, relay: bool, gpu: GpuId, topo: &Topology) -> f64 {
        let lat = topo.lat;
        let pcie = topo.pcie_capacity(gpu, self.dir);
        let fixed = (lat.dispatch_cpu_ns + lat.dma_setup_ns + lat.event_sync_ns) as f64 * 1e-9;
        let mut t = fixed + bytes as f64 / pcie;
        if relay {
            // Forwarding hop: NVLink stage + P2P launch.
            let nv = topo.capacity(topo.link(crate::topology::LinkKind::NvOut(gpu)));
            t += lat.p2p_setup_ns as f64 * 1e-9 + bytes as f64 / nv;
        }
        t
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::h20x8;

    fn desc(bytes: u64) -> TransferDesc {
        TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), bytes)
    }

    fn flow_keys(acts: &[EngineAction]) -> Vec<u64> {
        acts.iter()
            .filter_map(|a| match a {
                EngineAction::StartFlow { key, .. } => Some(*key),
                _ => None,
            })
            .collect()
    }

    /// Tiny sequential executor: runs the engine's action graph to
    /// quiescence with synthetic 1 us flow times. Returns completion info.
    fn drain(
        e: &mut Engine,
        topo: &Topology,
        init: Vec<EngineAction>,
    ) -> Vec<(TransferId, u64, u64)> {
        let mut pending: std::collections::VecDeque<EngineAction> = init.into();
        let mut now = Time::ZERO;
        let mut completes = Vec::new();
        let mut steps = 0u32;
        while let Some(act) = pending.pop_front() {
            steps += 1;
            assert!(steps < 1_000_000, "engine action graph does not quiesce");
            match act {
                EngineAction::StartFlow { key, .. } => {
                    now = now + Time::from_us(1);
                    pending.extend(e.on_flow_done(now, key, topo));
                }
                EngineAction::RetireAt { gpu, key, at } => {
                    now = now.max(at);
                    pending.extend(e.on_retire(now, gpu, key, topo));
                }
                EngineAction::WakeAt { gpu, at } => {
                    now = now.max(at);
                    pending.extend(e.on_wake(now, gpu, topo));
                }
                EngineAction::TransferComplete {
                    transfer,
                    bytes_direct,
                    bytes_relay,
                } => completes.push((transfer, bytes_direct, bytes_relay)),
            }
        }
        completes
    }

    /// Sink-based twin of `drain`: one reused [`ActionSink`] for every
    /// engine call, so the executor itself exercises the allocation-free
    /// path. Returns the number of completed transfers.
    fn drain_into(
        e: &mut Engine,
        topo: &Topology,
        sink: &mut ActionSink,
        pending: &mut std::collections::VecDeque<EngineAction>,
    ) -> u32 {
        let mut now = Time::ZERO;
        let mut completes = 0u32;
        let mut steps = 0u32;
        while let Some(act) = pending.pop_front() {
            steps += 1;
            assert!(steps < 1_000_000, "engine action graph does not quiesce");
            sink.clear();
            match act {
                EngineAction::StartFlow { key, .. } => {
                    now = now + Time::from_us(1);
                    e.on_flow_done_into(now, key, topo, sink);
                }
                EngineAction::RetireAt { gpu, key, at } => {
                    now = now.max(at);
                    e.on_retire_into(now, gpu, key, topo, sink);
                }
                EngineAction::WakeAt { gpu, at } => {
                    now = now.max(at);
                    e.on_wake_into(now, gpu, topo, sink);
                }
                EngineAction::TransferComplete { .. } => completes += 1,
            }
            pending.extend(sink.drain());
        }
        completes
    }

    #[test]
    fn activate_splits_and_wakes_all_workers() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        let acts = e.activate(Time::ZERO, TransferId(0), desc(50_000_000), &topo);
        let wakes = acts
            .iter()
            .filter(|a| matches!(a, EngineAction::WakeAt { .. }))
            .count();
        assert_eq!(wakes, 8);
        assert!(!e.is_idle());
        assert_eq!(e.active_transfers(), 1);
    }

    #[test]
    fn wake_fills_outstanding_queue_to_depth() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        e.activate(Time::ZERO, TransferId(0), desc(50_000_000), &topo);
        let acts = e.on_wake(Time::ZERO, GpuId(0), &topo);
        // Two slots occupied; only the first chunk's DMA starts (the second
        // queues behind it on the PCIe lane).
        assert_eq!(e.queues[0].slots.len(), 2);
        assert_eq!(flow_keys(&acts).len(), 1);
        // Re-waking without retirement does nothing (queue full).
        assert!(e.on_wake(Time::ZERO, GpuId(0), &topo).is_empty());
    }

    #[test]
    fn lane_serializes_back_to_back() {
        let topo = h20x8();
        let cfg = MmaConfig {
            relay_gpus: Some(vec![]),
            ..Default::default()
        };
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        e.activate(Time::ZERO, TransferId(0), desc(20_000_000), &topo);
        let acts = e.on_wake(Time::ZERO, GpuId(0), &topo);
        let keys = flow_keys(&acts);
        assert_eq!(keys, vec![0]);
        // First chunk's flow completes → lane hands off to chunk 1 with the
        // warm turnaround latency, and chunk 0 goes to retirement.
        let acts = e.on_flow_done(Time::from_us(100), keys[0], &topo);
        let mut saw_next = false;
        let mut saw_retire = false;
        for a in &acts {
            match a {
                EngineAction::StartFlow { key, latency, .. } => {
                    assert_eq!(*key, 1);
                    assert_eq!(latency.ns(), topo.lat.dma_turnaround_ns);
                    saw_next = true;
                }
                EngineAction::RetireAt { key, .. } => {
                    assert_eq!(*key, 0);
                    saw_retire = true;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(saw_next && saw_retire);
    }

    #[test]
    fn relay_two_stage_uses_pcie_then_nvlink() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        e.activate(Time::ZERO, TransferId(0), desc(50_000_000), &topo);
        let acts = e.on_wake(Time::ZERO, GpuId(1), &topo);
        let keys = flow_keys(&acts);
        assert_eq!(keys.len(), 1);
        // Stage 1 lands on the relay's own PCIe lane.
        let EngineAction::StartFlow { ref path, .. } = acts[0] else {
            panic!()
        };
        let kinds: Vec<_> = path.iter().map(|l| topo.links[l.0 as usize].kind).collect();
        assert!(kinds.contains(&crate::topology::LinkKind::PcieH2D(GpuId(1))));
        // Stage 1 done → next queued stage-1 starts AND stage 2 launches
        // over NVLink to the target (two different lanes: dual pipeline).
        let acts2 = e.on_flow_done(Time::from_us(100), keys[0], &topo);
        let stage2 = acts2
            .iter()
            .find_map(|a| match a {
                EngineAction::StartFlow { key, path, .. } if *key == keys[0] => Some(path),
                _ => None,
            })
            .expect("stage 2 flow missing: {acts2:?}");
        let kinds2: Vec<_> = stage2.iter().map(|l| topo.links[l.0 as usize].kind).collect();
        assert!(kinds2.contains(&crate::topology::LinkKind::NvOut(GpuId(1))));
        assert!(kinds2.contains(&crate::topology::LinkKind::NvIn(GpuId(0))));
        // The other action is the next chunk's stage 1 on the PCIe lane.
        let next = acts2
            .iter()
            .find_map(|a| match a {
                EngineAction::StartFlow { key, path, .. } if *key != keys[0] => Some(path),
                _ => None,
            })
            .expect("queued stage 1 missing");
        let kinds3: Vec<_> = next.iter().map(|l| topo.links[l.0 as usize].kind).collect();
        assert!(kinds3.contains(&crate::topology::LinkKind::PcieH2D(GpuId(1))));
        // Stage 2 completion retires via the sync thread.
        let acts3 = e.on_flow_done(Time::from_us(200), keys[0], &topo);
        assert!(
            acts3
                .iter()
                .any(|a| matches!(a, EngineAction::RetireAt { key, .. } if *key == keys[0])),
            "{acts3:?}"
        );
    }

    #[test]
    fn full_transfer_direct_only_completes_with_split() {
        let topo = h20x8();
        let cfg = MmaConfig {
            relay_gpus: Some(vec![]), // direct only
            ..Default::default()
        };
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        let init = e.activate(Time::ZERO, TransferId(5), desc(8_000_000), &topo);
        let completes = drain(&mut e, &topo, init);
        assert_eq!(completes, vec![(TransferId(5), 8_000_000, 0)]);
        assert!(e.is_idle());
        assert_eq!(e.stats.transfers_completed, 1);
    }

    #[test]
    fn full_transfer_with_relays_splits_bytes() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        let init = e.activate(Time::ZERO, TransferId(2), desc(100_000_000), &topo);
        let completes = drain(&mut e, &topo, init);
        assert_eq!(completes.len(), 1);
        let (t, bd, br) = completes[0];
        assert_eq!(t, TransferId(2));
        assert_eq!(bd + br, 100_000_000);
        assert!(br > 0, "relays never used");
        assert!(e.is_idle());
    }

    #[test]
    fn d2h_transfer_completes() {
        let topo = h20x8();
        let mut e = Engine::new(1, Direction::D2H, MmaConfig::default(), 8);
        let d = TransferDesc::new(Direction::D2H, GpuId(3), NumaId(0), 40_000_000);
        let init = e.activate(Time::ZERO, TransferId(7), d, &topo);
        let completes = drain(&mut e, &topo, init);
        assert_eq!(completes.len(), 1);
        assert_eq!(completes[0].1 + completes[0].2, 40_000_000);
    }

    #[test]
    fn single_pipeline_limits_relay_to_one_inflight() {
        let topo = h20x8();
        let cfg = MmaConfig {
            dual_pipeline: false,
            ..Default::default()
        };
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        e.activate(Time::ZERO, TransferId(0), desc(100_000_000), &topo);
        e.on_wake(Time::ZERO, GpuId(3), &topo);
        assert_eq!(e.queues[3].slots.len(), 1, "single pipeline: one relay slot");
        let mut e2 = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        e2.activate(Time::ZERO, TransferId(0), desc(100_000_000), &topo);
        e2.on_wake(Time::ZERO, GpuId(3), &topo);
        assert_eq!(e2.queues[3].slots.len(), 2, "dual pipeline: two relay slots");
    }

    #[test]
    fn static_policy_assigns_by_ratio() {
        let topo = h20x8();
        let cfg = MmaConfig {
            policy: crate::policy::PolicySpec::Static(vec![(GpuId(0), 1.0), (GpuId(1), 2.0)]),
            ..Default::default()
        };
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        // 30 MB → 6 chunks; 1:2 split → 2 direct on gpu0, 4 relayed by gpu1.
        let init = e.activate(Time::ZERO, TransferId(0), desc(30_000_000), &topo);
        let completes = drain(&mut e, &topo, init);
        assert_eq!(completes.len(), 1);
        assert_eq!(e.stats.chunks_dispatched[0], 2);
        assert_eq!(e.stats.chunks_dispatched[1], 4);
        assert_eq!(completes[0].1, 10_000_000); // direct bytes
        assert_eq!(completes[0].2, 20_000_000); // relay bytes
    }

    #[test]
    fn qos_critical_chunks_issue_before_earlier_bulk_ones() {
        // Same destination, bulk transfer activated first: with QoS on the
        // later latency-critical transfer's chunks pull first and it
        // completes first; with QoS off, FIFO lets the bulk one win.
        let topo = h20x8();
        let run = |qos_on: bool| {
            let mut cfg = MmaConfig {
                relay_gpus: Some(vec![]), // direct-only: one queue, clear ordering
                ..Default::default()
            };
            cfg.qos.enabled = qos_on;
            let mut e = Engine::new(0, Direction::H2D, cfg, 8);
            let bulk = desc(30_000_000).with_class(super::TransferClass::Bulk);
            let crit = desc(30_000_000).with_class(super::TransferClass::LatencyCritical);
            let mut init = e.activate(Time::ZERO, TransferId(0), bulk, &topo);
            init.extend(e.activate(Time::ZERO, TransferId(1), crit, &topo));
            let completes = drain(&mut e, &topo, init);
            assert_eq!(completes.len(), 2);
            completes[0].0 // first transfer to finish
        };
        assert_eq!(run(false), TransferId(0), "FIFO: earlier bulk transfer first");
        assert_eq!(run(true), TransferId(1), "QoS: critical transfer leapfrogs");
    }

    #[test]
    fn qos_throttles_bulk_to_one_outstanding_slot_while_critical_live() {
        let topo = h20x8();
        let mut cfg = MmaConfig {
            relay_gpus: Some(vec![]),
            ..Default::default()
        };
        cfg.qos.enabled = true;
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        // Bulk work for gpu0, critical work pending for gpu1: gpu0's queue
        // takes one bulk chunk and then stops (depth throttle) instead of
        // filling both slots.
        e.activate(
            Time::ZERO,
            TransferId(0),
            desc(40_000_000).with_class(super::TransferClass::Bulk),
            &topo,
        );
        e.activate(
            Time::ZERO,
            TransferId(1),
            TransferDesc::new(Direction::H2D, GpuId(1), NumaId(0), 40_000_000)
                .with_class(super::TransferClass::LatencyCritical),
            &topo,
        );
        e.on_wake(Time::ZERO, GpuId(0), &topo);
        assert_eq!(
            e.queues[0].slots.len(),
            1,
            "bulk capped at one slot while critical work is live"
        );
        // Without live critical work the same wake fills the full depth.
        let mut cfg2 = MmaConfig {
            relay_gpus: Some(vec![]),
            ..Default::default()
        };
        cfg2.qos.enabled = true;
        let mut e2 = Engine::new(0, Direction::H2D, cfg2, 8);
        e2.activate(
            Time::ZERO,
            TransferId(0),
            desc(40_000_000).with_class(super::TransferClass::Bulk),
            &topo,
        );
        e2.on_wake(Time::ZERO, GpuId(0), &topo);
        assert_eq!(e2.queues[0].slots.len(), 2, "no critical work → full depth");
    }

    #[test]
    fn qos_lane_queue_reorders_waiting_flows_by_class() {
        // Force two waiting descriptors behind an active copy on gpu0's
        // PCIe lane; under QoS the critical one must launch first when the
        // lane frees even though the bulk one queued earlier.
        let topo = h20x8();
        let mut cfg = MmaConfig {
            relay_gpus: Some(vec![]),
            outstanding_depth: 3,
            ..Default::default()
        };
        cfg.qos.enabled = true;
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        // One critical chunk (launches, occupies the lane), then a bulk
        // and another critical transfer whose chunks queue behind it.
        e.activate(
            Time::ZERO,
            TransferId(0),
            desc(5_000_000).with_class(super::TransferClass::LatencyCritical),
            &topo,
        );
        let acts = e.on_wake(Time::ZERO, GpuId(0), &topo);
        let first = flow_keys(&acts);
        assert_eq!(first.len(), 1, "one active copy on the lane");
        e.activate(
            Time::ZERO,
            TransferId(1),
            desc(5_000_000).with_class(super::TransferClass::Bulk),
            &topo,
        );
        e.on_wake(Time::ZERO, GpuId(0), &topo);
        e.activate(
            Time::ZERO,
            TransferId(2),
            desc(5_000_000).with_class(super::TransferClass::LatencyCritical),
            &topo,
        );
        e.on_wake(Time::ZERO, GpuId(0), &topo);
        // Lane frees → the *critical* waiter launches, not the bulk one
        // that queued first.
        let acts = e.on_flow_done(Time::from_us(200), first[0], &topo);
        let next = acts
            .iter()
            .find_map(|a| match a {
                EngineAction::StartFlow { key, .. } => Some(*key),
                _ => None,
            })
            .expect("lane hand-off");
        let nxt = *e.inflight.get(next as u32).expect("hand-off key live");
        assert_eq!(nxt.class, super::TransferClass::LatencyCritical);
        assert_eq!(nxt.chunk.transfer, TransferId(2));
    }

    #[test]
    fn contention_marks_backs_off_and_clears() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        e.activate(Time::ZERO, TransferId(0), desc(40_000_000), &topo);
        let acts = e.on_wake(Time::ZERO, GpuId(0), &topo);
        let k0 = flow_keys(&acts)[0];
        // Deliver chunk 0 absurdly late → contended on retire.
        let acts = e.on_flow_done(Time::from_ms(50), k0, &topo);
        let k1 = flow_keys(&acts)[0]; // queued chunk launches
        let EngineAction::RetireAt { gpu, key, at } = acts
            .iter()
            .find(|a| matches!(a, EngineAction::RetireAt { .. }))
            .cloned()
            .unwrap()
        else {
            panic!()
        };
        e.on_retire(at, gpu, key, &topo);
        assert!(e.queues[0].contended);
        assert_eq!(e.stats.backoff_events[0], 1);
        // Chunk 1 also late → still contended; queue now has 1 slot free
        // but backoff caps effective depth at 1 → pulls only one chunk.
        let acts = e.on_flow_done(Time::from_ms(51), k1, &topo);
        let EngineAction::RetireAt { gpu, key, at } = acts
            .iter()
            .find(|a| matches!(a, EngineAction::RetireAt { .. }))
            .cloned()
            .unwrap()
        else {
            panic!()
        };
        let retire_acts = e.on_retire(at, gpu, key, &topo);
        let wake_at = at;
        assert!(e.queues[0].contended);
        // Retirement inlines the worker wake: the pull happens right in
        // the returned actions — exactly one chunk under backoff.
        let keys = flow_keys(&retire_acts);
        assert_eq!(keys.len(), 1, "backoff must reduce depth to 1");
        assert_eq!(e.queues[0].slots.len(), 1);
        // On-time delivery clears the contention mark.
        let (k2, lat2, b2) = retire_acts
            .iter()
            .find_map(|a| match a {
                EngineAction::StartFlow { key, latency, bytes, .. } => {
                    Some((*key, *latency, *bytes))
                }
                _ => None,
            })
            .unwrap();
        let on_time = wake_at + lat2 + Time::from_secs_f64(b2 as f64 / 53.6e9);
        let acts = e.on_flow_done(on_time, k2, &topo);
        let EngineAction::RetireAt { gpu, key, at } = acts
            .iter()
            .find(|a| matches!(a, EngineAction::RetireAt { .. }))
            .cloned()
            .unwrap()
        else {
            panic!()
        };
        e.on_retire(at, gpu, key, &topo);
        assert!(!e.queues[0].contended, "clean completion must clear backoff");
    }

    #[test]
    fn stray_completion_and_retire_are_counted_not_fatal() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        let init = e.activate(Time::ZERO, TransferId(0), desc(8_000_000), &topo);
        // Key outside the 24-bit key space, a never-issued in-range key,
        // and a retire for the same: all skipped and counted.
        assert!(e.on_flow_done(Time::ZERO, 1 << 30, &topo).is_empty());
        assert!(e.on_flow_done(Time::ZERO, 0xFFFF, &topo).is_empty());
        assert!(e.on_retire(Time::ZERO, GpuId(0), 0xFFFF, &topo).is_empty());
        assert_eq!(e.stats.stray_events, 3);
        // The replay continues unharmed: the transfer still completes.
        let completes = drain(&mut e, &topo, init);
        assert_eq!(completes.len(), 1);
        assert!(e.is_idle());
        // A duplicate retire of an already-retired chunk (its slab slot's
        // generation has moved on) is also just counted.
        assert!(e.on_retire(Time::ZERO, GpuId(0), 0, &topo).is_empty());
        assert_eq!(e.stats.stray_events, 4);
    }

    #[test]
    fn reused_sink_stops_growing_after_warmup() {
        // The zero-allocation contract, observable without a counting
        // allocator: after one warm-up transfer has sized the reused sink
        // (and the engine's internal scratch), an identical follow-up
        // transfer must not grow the sink again.
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        let mut sink = ActionSink::new();
        let mut pending = std::collections::VecDeque::new();
        sink.clear();
        e.activate_into(Time::ZERO, TransferId(0), desc(50_000_000), &topo, &mut sink);
        pending.extend(sink.drain());
        assert_eq!(drain_into(&mut e, &topo, &mut sink, &mut pending), 1);
        let warm_grows = sink.grows();
        let warm_pushed = sink.pushed();
        sink.clear();
        e.activate_into(Time::ZERO, TransferId(1), desc(50_000_000), &topo, &mut sink);
        pending.extend(sink.drain());
        assert_eq!(drain_into(&mut e, &topo, &mut sink, &mut pending), 1);
        assert!(e.is_idle());
        assert_eq!(
            sink.grows(),
            warm_grows,
            "sink re-allocated on the steady-state path"
        );
        assert!(sink.pushed() > warm_pushed, "second transfer pushed actions");
    }

    #[test]
    fn property_sink_engine_matches_vec_reference_under_churn() {
        // The slab/sink engine must emit an action stream identical to the
        // legacy Vec wrappers under randomized chunk churn: random transfer
        // mixes, random completion interleavings, stray keys injected
        // mid-run. Engine A runs the Vec API, engine B the `_into` API with
        // one reused sink; every step's streams must match, and final
        // stats must agree.
        let topo = h20x8();
        let classes = [
            TransferClass::LatencyCritical,
            TransferClass::Interactive,
            TransferClass::Bulk,
            TransferClass::Background,
        ];
        crate::testkit::check("engine_sink_vs_vec_churn", |rng| {
            let mut cfg = MmaConfig { ..Default::default() };
            cfg.qos.enabled = rng.bool(0.5);
            let mut ea = Engine::new(0, Direction::H2D, cfg.clone(), 8);
            let mut eb = Engine::new(0, Direction::H2D, cfg, 8);
            let mut sink = ActionSink::new();
            let mut pending: std::collections::VecDeque<EngineAction> =
                std::collections::VecDeque::new();
            let n_transfers = rng.range_usize(1, 4);
            for t in 0..n_transfers {
                let bytes = rng.range_u64(1, 9) * 5_000_000;
                let d = desc(bytes).with_class(*rng.choose(&classes));
                let a = ea.activate(Time::ZERO, TransferId(t as u32), d, &topo);
                sink.clear();
                eb.activate_into(Time::ZERO, TransferId(t as u32), d, &topo, &mut sink);
                assert_eq!(a.as_slice(), sink.as_slice());
                pending.extend(a);
            }
            let mut now = Time::ZERO;
            let mut steps = 0u32;
            let mut bytes_done = 0u64;
            while !pending.is_empty() {
                steps += 1;
                assert!(steps < 1_000_000, "churn executor does not quiesce");
                if rng.bool(0.05) {
                    // Stray completion for a key that can never be live.
                    let bogus = (1u64 << 24) + rng.range_u64(0, 100);
                    let a = ea.on_flow_done(now, bogus, &topo);
                    sink.clear();
                    eb.on_flow_done_into(now, bogus, &topo, &mut sink);
                    assert!(a.is_empty() && sink.is_empty());
                }
                // Random event order (per-key causality is preserved
                // because a key's next event only enqueues after its
                // previous one ran).
                let i = rng.range_usize(0, pending.len());
                let act = pending.remove(i).unwrap();
                let a = match act {
                    EngineAction::StartFlow { key, .. } => {
                        now = now + Time::from_us(rng.range_u64(1, 50));
                        let a = ea.on_flow_done(now, key, &topo);
                        sink.clear();
                        eb.on_flow_done_into(now, key, &topo, &mut sink);
                        a
                    }
                    EngineAction::RetireAt { gpu, key, at } => {
                        now = now.max(at);
                        let a = ea.on_retire(now, gpu, key, &topo);
                        sink.clear();
                        eb.on_retire_into(now, gpu, key, &topo, &mut sink);
                        a
                    }
                    EngineAction::WakeAt { gpu, at } => {
                        now = now.max(at);
                        let a = ea.on_wake(now, gpu, &topo);
                        sink.clear();
                        eb.on_wake_into(now, gpu, &topo, &mut sink);
                        a
                    }
                    EngineAction::TransferComplete {
                        bytes_direct,
                        bytes_relay,
                        ..
                    } => {
                        bytes_done += bytes_direct + bytes_relay;
                        continue;
                    }
                };
                assert_eq!(a.as_slice(), sink.as_slice(), "streams diverged");
                pending.extend(a);
            }
            assert!(ea.is_idle() && eb.is_idle());
            assert_eq!(ea.stats.transfers_completed, n_transfers as u64);
            assert_eq!(ea.stats.transfers_completed, eb.stats.transfers_completed);
            assert_eq!(ea.stats.stray_events, eb.stats.stray_events);
            assert_eq!(ea.stats.chunks_dispatched, eb.stats.chunks_dispatched);
            assert!(bytes_done > 0);
        });
    }
}
