//! Multipath Transfer Engine (§3.4): per-direction engine instances that
//! split transfers into micro-tasks, pull them into per-link outstanding
//! queues, and launch direct/relay DMA — including the Task Launcher's
//! two-stage relay with dual-pipeline overlap (Fig 6).
//!
//! The engine is a passive state machine: the driver feeds it events
//! (`activate`, `on_wake`, `on_flow_done`, `on_retire`) and executes the
//! returned [`EngineAction`]s against the fabric and event queue. In the
//! paper these transitions run on per-GPU *transfer* and *synchronization*
//! threads; the virtual-time model preserves their scheduling behaviour
//! (dispatch serialization, `cudaEventSynchronize` wake-up latency) via
//! explicit latency terms, and accounts their CPU burn in
//! [`super::stats::EngineStats`].

use super::stats::EngineStats;
use super::task_manager::{Chunk, PullClassPolicy, TaskManager};
use super::transfer_task::{TransferClass, TransferDesc};
use super::MmaConfig;
use crate::gpusim::TransferId;
use crate::policy::{OutstandingQueue, PolicyView, Pulled, TransferPolicy};
use crate::sim::Time;
use crate::topology::{Direction, GpuId, LinkId, NumaId, Topology};
use crate::util::fxmap::FxHashMap;
use std::collections::VecDeque;

/// What the driver must do on the engine's behalf.
#[derive(Debug, Clone)]
pub enum EngineAction {
    /// Launch a DMA flow for a micro-task stage.
    StartFlow {
        /// In-flight chunk key (routes the completion back).
        key: u64,
        /// Links the flow traverses.
        path: Vec<LinkId>,
        /// Bytes.
        bytes: u64,
        /// Setup latency before the flow occupies bandwidth.
        latency: Time,
        /// QoS traffic class (fabric share weight + sampling channel).
        class: TransferClass,
        /// True when this stage delivers the chunk to its destination
        /// (direct, or the relay's forwarding hop). Bandwidth sampling
        /// counts only terminal stages, so relayed bytes aren't counted
        /// twice.
        terminal: bool,
    },
    /// Wake the worker for `gpu` at `at` (schedule `on_wake`).
    WakeAt {
        /// Worker's GPU.
        gpu: GpuId,
        /// When.
        at: Time,
    },
    /// The sync thread retires chunk `key` at `at` (schedule `on_retire`).
    RetireAt {
        /// Owning queue's GPU.
        gpu: GpuId,
        /// Chunk key.
        key: u64,
        /// When (delivery + `cudaEventSynchronize` wake-up).
        at: Time,
    },
    /// Every micro-task of `transfer` has landed and been retired.
    TransferComplete {
        /// The finished transfer.
        transfer: TransferId,
        /// Bytes that took the direct path.
        bytes_direct: u64,
        /// Bytes that took relay paths.
        bytes_relay: u64,
    },
}

#[derive(Debug, Clone)]
struct ActiveTransfer {
    desc: TransferDesc,
    total_chunks: u32,
    retired_chunks: u32,
    bytes_direct: u64,
    bytes_relay: u64,
}

#[derive(Debug, Clone, Copy)]
struct InFlight {
    chunk: Chunk,
    /// The PCIe-link GPU whose outstanding queue holds this chunk.
    path_gpu: GpuId,
    relay: bool,
    host_numa: NumaId,
    dispatched: Time,
    stage: u8,
    /// QoS class of the parent transfer (carried by the chunk; cached so
    /// retirement can update per-class queue counts without a lookup).
    class: TransferClass,
    /// Uncontended expected service time (for contention inference),
    /// accounting for chunks queued ahead on the same lane at dispatch.
    expected_s: f64,
}

/// Which per-GPU DMA lane a stage occupies. Copies queued on the same lane
/// execute back-to-back (one copy engine per lane per direction), which is
/// what lets depth-2 outstanding queues pipeline without bubbles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LaneKind {
    /// The GPU's PCIe copy engine for this engine's direction.
    Pcie = 0,
    /// The GPU's P2P (NVLink) copy engine.
    Nv = 1,
}

/// A flow whose DMA descriptor is programmed but waiting behind the lane's
/// active copy.
#[derive(Debug, Clone)]
struct QueuedFlow {
    key: u64,
    path: Vec<LinkId>,
    bytes: u64,
    class: TransferClass,
    terminal: bool,
}

/// One GPU's pair of serializing DMA lanes.
#[derive(Debug, Default)]
struct Lanes {
    active: [Option<u64>; 2],
    waiting: [VecDeque<QueuedFlow>; 2],
}

impl Lanes {
    fn occupancy(&self, lane: LaneKind) -> usize {
        let i = lane as usize;
        self.active[i].is_some() as usize + self.waiting[i].len()
    }
}

/// One direction's Multipath Transfer Engine.
pub struct Engine {
    /// Engine index within the driver.
    pub id: u8,
    /// Direction this instance serves (H2D and D2H run separately, §4).
    pub dir: Direction,
    /// Tunables.
    pub cfg: MmaConfig,
    /// The pluggable chunk→path placement strategy (built from
    /// `cfg.policy`; each engine instance carries its own state).
    policy: Box<dyn TransferPolicy>,
    tm: TaskManager,
    queues: Vec<OutstandingQueue>,
    lanes: Vec<Lanes>,
    relay_inflight: Vec<u32>,
    inflight: FxHashMap<u64, InFlight>,
    next_key: u64,
    transfers: FxHashMap<u32, ActiveTransfer>,
    /// Counters (Fig 11 CPU accounting, relay/direct byte split).
    pub stats: EngineStats,
    central_busy_until: Time,
}

impl Engine {
    /// New engine over `gpu_count` PCIe links.
    pub fn new(id: u8, dir: Direction, cfg: MmaConfig, gpu_count: usize) -> Engine {
        Engine {
            id,
            dir,
            policy: cfg.policy.build(&cfg),
            tm: TaskManager::new(gpu_count),
            queues: (0..gpu_count)
                .map(|g| OutstandingQueue::new(GpuId(g as u8), cfg.outstanding_depth))
                .collect(),
            lanes: (0..gpu_count).map(|_| Lanes::default()).collect(),
            relay_inflight: vec![0; gpu_count],
            inflight: FxHashMap::default(),
            next_key: 0,
            transfers: FxHashMap::default(),
            stats: EngineStats::new(gpu_count),
            central_busy_until: Time::ZERO,
            cfg,
        }
    }

    /// Any work queued or in flight?
    pub fn is_idle(&self) -> bool {
        self.tm.is_empty() && self.inflight.is_empty()
    }

    /// The live placement policy (read-only: decision surfaces that sit
    /// outside the chunk→path hot loop, e.g. the serving layer's
    /// host-vs-peer fetch choice).
    pub fn policy(&self) -> &dyn TransferPolicy {
        &*self.policy
    }

    /// Number of live transfers.
    pub fn active_transfers(&self) -> usize {
        self.transfers.len()
    }

    /// The copy point of `transfer` is active (§3.1 step ②→③): split into
    /// micro-tasks, hand them to the policy, and wake the workers.
    pub fn activate(
        &mut self,
        now: Time,
        transfer: TransferId,
        desc: TransferDesc,
        topo: &Topology,
    ) -> Vec<EngineAction> {
        let chunks =
            TaskManager::split(transfer, desc.gpu, desc.bytes, self.cfg.chunk_bytes, desc.class);
        let total = chunks.len() as u32;
        self.transfers.insert(
            transfer.0,
            ActiveTransfer {
                desc,
                total_chunks: total,
                retired_chunks: 0,
                bytes_direct: 0,
                bytes_relay: 0,
            },
        );
        let view = PolicyView {
            topo,
            dir: self.dir,
            queues: &self.queues,
            now,
            class_pull: PullClassPolicy {
                by_class: self.cfg.qos.enabled,
                ..Default::default()
            },
            class_pending: self.tm.pending_by_class(),
        };
        self.policy.admit(&chunks, &mut self.tm, &view);
        // Wake every worker after the fixed activation overhead; workers
        // with no eligible work simply find nothing to pull.
        let at = now + Time::from_ns(self.cfg.activation_ns);
        (0..self.queues.len())
            .map(|g| EngineAction::WakeAt {
                gpu: GpuId(g as u8),
                at,
            })
            .collect()
    }

    /// Transfer-thread wake-up for `gpu`: pull micro-tasks while the
    /// outstanding queue has capacity, dispatching each (§3.4.2/§3.4.3).
    pub fn on_wake(&mut self, now: Time, gpu: GpuId, topo: &Topology) -> Vec<EngineAction> {
        let mut actions = Vec::new();
        loop {
            let gi = gpu.0 as usize;
            if !self.queues[gi].has_capacity(self.cfg.contention_backoff) {
                break;
            }
            // Naive single-pipeline relay (Fig 6a ablation): at most one
            // relay micro-task in flight per relay GPU.
            let relay_blocked = !self.cfg.dual_pipeline && self.relay_inflight[gi] > 0;
            let pulled = if relay_blocked && !self.tm.has_direct(gpu) {
                None
            } else {
                let view = PolicyView {
                    topo,
                    dir: self.dir,
                    queues: &self.queues,
                    now,
                    class_pull: self.class_pull(gi),
                    class_pending: self.tm.pending_by_class(),
                };
                self.policy.pull(&mut self.tm, gpu, &view)
            };
            let Some(pulled) = pulled else { break };
            actions.extend(self.dispatch(now, gpu, pulled, topo));
        }
        actions
    }

    /// QoS class policy for one of `gpu`'s pull rounds. All-false while
    /// QoS is disabled (legacy FIFO). Enabled:
    ///
    /// * pops are class-prioritized (`by_class`);
    /// * a queue already holding a bulk-band chunk in flight pulls only
    ///   critical-band work while critical flows are live anywhere — the
    ///   outstanding-depth throttle that caps bulk at one slot under
    ///   contention with latency-critical traffic (`critical_only`);
    /// * a queue with an in-flight critical chunk refuses to steal
    ///   bulk-band work onto its path (`no_bulk_steal`; the guard itself
    ///   lives in [`TaskManager::pop_steal_scored`]).
    fn class_pull(&self, gi: usize) -> PullClassPolicy {
        if !self.cfg.qos.enabled {
            return PullClassPolicy::default();
        }
        let critical_live = self.tm.critical_pending() > 0
            || self.queues.iter().any(|q| q.critical_inflight > 0);
        PullClassPolicy {
            by_class: true,
            critical_only: critical_live && self.queues[gi].bulk_inflight > 0,
            no_bulk_steal: self.queues[gi].critical_inflight > 0,
        }
    }

    /// Dispatch one pulled micro-task through the Task Launcher.
    fn dispatch(
        &mut self,
        now: Time,
        gpu: GpuId,
        pulled: Pulled,
        topo: &Topology,
    ) -> Vec<EngineAction> {
        let chunk = pulled.chunk();
        let relay = pulled.is_relay();
        let gi = gpu.0 as usize;
        let host_numa = self
            .transfers
            .get(&chunk.transfer.0)
            .map(|t| t.desc.host_numa)
            .expect("chunk for unknown transfer");
        let class = chunk.class;

        // Transfer-thread dispatch serialization: the (per-GPU or central)
        // worker burns `dispatch_cpu_ns` per micro-task.
        let lat = topo.lat;
        let busy = if self.cfg.centralized_dispatch {
            &mut self.central_busy_until
        } else {
            &mut self.queues[gi].busy_until
        };
        let start = (*busy).max(now) + Time::from_ns(lat.dispatch_cpu_ns);
        *busy = start;
        let cpu_wait = start.since(now);

        let key = self.next_key;
        self.next_key += 1;
        if self.queues[gi].slots.is_empty() {
            self.stats.queue_busy(gpu, now);
        }
        self.queues[gi].occupy(key, class);
        if relay {
            self.relay_inflight[gi] += 1;
        }
        self.stats
            .dispatched(gpu, chunk.bytes, relay, lat.dispatch_cpu_ns);

        // Stage-1 path + lane (§3.4.3 Task Launcher).
        let (path, setup, lane) = match (self.dir, relay) {
            (Direction::H2D, false) => (
                topo.h2d_direct(host_numa, chunk.dest),
                lat.dma_setup_ns,
                LaneKind::Pcie,
            ),
            (Direction::H2D, true) => (
                topo.h2d_relay_stage1(host_numa, gpu),
                lat.dma_setup_ns,
                LaneKind::Pcie,
            ),
            (Direction::D2H, false) => (
                topo.d2h_direct(chunk.dest, host_numa),
                lat.dma_setup_ns,
                LaneKind::Pcie,
            ),
            (Direction::D2H, true) => (
                topo.d2h_relay_stage1(chunk.dest, gpu),
                lat.p2p_setup_ns,
                LaneKind::Nv,
            ),
        };
        let ahead = self.lanes[gi].occupancy(lane);
        let expected_s =
            self.expected_service_secs(chunk.bytes, relay, gpu, topo) * (ahead as f64 + 1.0);
        self.inflight.insert(
            key,
            InFlight {
                chunk,
                path_gpu: gpu,
                relay,
                host_numa,
                dispatched: now,
                stage: 1,
                class,
                expected_s,
            },
        );
        self.lane_submit(
            gpu,
            lane,
            QueuedFlow {
                key,
                path,
                bytes: chunk.bytes,
                class,
                terminal: !relay,
            },
            cpu_wait + Time::from_ns(setup),
        )
        .into_iter()
        .collect()
    }

    /// Submit a stage's flow to a serializing DMA lane. If the lane is
    /// busy, the descriptor queues behind the active copy and launches
    /// back-to-back when it finishes (returns no action yet). Under QoS,
    /// waiting descriptors are ordered by class priority (FIFO within a
    /// class): a latency-critical chunk issues before queued bulk ones.
    fn lane_submit(
        &mut self,
        gpu: GpuId,
        lane: LaneKind,
        flow: QueuedFlow,
        cold_latency: Time,
    ) -> Option<EngineAction> {
        let by_class = self.cfg.qos.enabled;
        let li = lane as usize;
        let lanes = &mut self.lanes[gpu.0 as usize];
        if lanes.active[li].is_none() {
            lanes.active[li] = Some(flow.key);
            Some(EngineAction::StartFlow {
                key: flow.key,
                path: flow.path,
                bytes: flow.bytes,
                latency: cold_latency,
                class: flow.class,
                terminal: flow.terminal,
            })
        } else {
            let w = &mut lanes.waiting[li];
            let pos = if by_class {
                w.iter().position(|q| q.class > flow.class).unwrap_or(w.len())
            } else {
                w.len()
            };
            w.insert(pos, flow);
            None
        }
    }

    /// A lane's active copy finished: hand the lane to the next queued
    /// descriptor (warm turnaround).
    fn lane_release(
        &mut self,
        gpu: GpuId,
        lane: LaneKind,
        key: u64,
        topo: &Topology,
    ) -> Option<EngineAction> {
        let li = lane as usize;
        let lanes = &mut self.lanes[gpu.0 as usize];
        debug_assert_eq!(lanes.active[li], Some(key), "lane released by non-owner");
        lanes.active[li] = None;
        let next = lanes.waiting[li].pop_front()?;
        lanes.active[li] = Some(next.key);
        Some(EngineAction::StartFlow {
            key: next.key,
            path: next.path,
            bytes: next.bytes,
            latency: Time::from_ns(topo.lat.dma_turnaround_ns),
            class: next.class,
            terminal: next.terminal,
        })
    }

    /// Lane used by a chunk's current stage.
    fn lane_of(&self, inf: &InFlight) -> LaneKind {
        match (self.dir, inf.relay, inf.stage) {
            (_, false, _) => LaneKind::Pcie,
            (Direction::H2D, true, 1) => LaneKind::Pcie,
            (Direction::H2D, true, _) => LaneKind::Nv,
            (Direction::D2H, true, 1) => LaneKind::Nv,
            (Direction::D2H, true, _) => LaneKind::Pcie,
        }
    }

    /// A micro-task stage's DMA finished.
    pub fn on_flow_done(&mut self, now: Time, key: u64, topo: &Topology) -> Vec<EngineAction> {
        let inf = *self.inflight.get(&key).expect("unknown chunk key");
        let lat = topo.lat;
        let mut actions = Vec::new();
        // Free the lane this stage occupied; the next queued descriptor
        // launches back-to-back.
        let done_lane = self.lane_of(&inf);
        actions.extend(self.lane_release(inf.path_gpu, done_lane, key, topo));

        if inf.relay && inf.stage == 1 {
            // Launch stage 2: the forwarding hop. Explicit stream
            // dependencies order the two stages (§3.4.3); the dual-pipeline
            // overlap comes from the second outstanding slot running its
            // stage 1 on the other lane concurrently (Fig 6b).
            let (path, setup, lane) = match self.dir {
                Direction::H2D => (
                    topo.h2d_relay_stage2(inf.path_gpu, inf.chunk.dest),
                    lat.p2p_setup_ns,
                    LaneKind::Nv,
                ),
                Direction::D2H => (
                    topo.d2h_relay_stage2(inf.path_gpu, inf.host_numa),
                    lat.dma_setup_ns,
                    LaneKind::Pcie,
                ),
            };
            self.inflight.get_mut(&key).unwrap().stage = 2;
            actions.extend(self.lane_submit(
                inf.path_gpu,
                lane,
                QueuedFlow {
                    key,
                    path,
                    bytes: inf.chunk.bytes,
                    class: inf.class,
                    terminal: true,
                },
                Time::from_ns(setup),
            ));
            return actions;
        }
        // Delivered: the sync thread observes completion after the
        // cudaEventSynchronize wake-up latency, then retires the slot.
        actions.push(EngineAction::RetireAt {
            gpu: inf.path_gpu,
            key,
            at: now + Time::from_ns(lat.event_sync_ns),
        });
        actions
    }

    /// Sync-thread retirement of a chunk: free the slot, detect contention,
    /// account transfer progress, and pull more work.
    pub fn on_retire(
        &mut self,
        now: Time,
        gpu: GpuId,
        key: u64,
        topo: &Topology,
    ) -> Vec<EngineAction> {
        let inf = self.inflight.remove(&key).expect("retire unknown chunk");
        debug_assert_eq!(inf.path_gpu, gpu);
        let gi = gpu.0 as usize;
        let retired = self.queues[gi].retire(key, inf.class);
        debug_assert!(retired);
        if inf.relay {
            self.relay_inflight[gi] -= 1;
        }
        if self.queues[gi].slots.is_empty() {
            self.stats.queue_idle(gpu, now);
        }

        // Feed the completion back to the policy (its congestion signal).
        let observed = now.since(inf.dispatched).as_secs_f64();
        self.policy
            .on_completion(gpu, inf.chunk.bytes, inf.relay, observed, inf.expected_s);

        // Contention inference (§3.4.2): completion far beyond the
        // uncontended expectation marks the path contended; a clean
        // completion clears it.
        if self.cfg.contention_backoff {
            let was = self.queues[gi].contended;
            self.queues[gi].contended = observed > self.cfg.contention_beta * inf.expected_s;
            if self.queues[gi].contended && !was {
                self.stats.backoff_events[gi] += 1;
            }
        }

        let mut actions = Vec::new();
        // Transfer progress.
        let done = {
            let t = self
                .transfers
                .get_mut(&inf.chunk.transfer.0)
                .expect("retire for unknown transfer");
            t.retired_chunks += 1;
            if inf.relay {
                t.bytes_relay += inf.chunk.bytes;
            } else {
                t.bytes_direct += inf.chunk.bytes;
            }
            t.retired_chunks == t.total_chunks
        };
        if done {
            let t = self.transfers.remove(&inf.chunk.transfer.0).unwrap();
            self.stats.transfers_completed += 1;
            actions.push(EngineAction::TransferComplete {
                transfer: inf.chunk.transfer,
                bytes_direct: t.bytes_direct,
                bytes_relay: t.bytes_relay,
            });
        }
        // Freed a slot: pull again immediately. Inlined rather than
        // emitting `WakeAt {now}` — saves one event-queue round trip per
        // retired chunk (see EXPERIMENTS.md §Perf).
        actions.extend(self.on_wake(now, gpu, topo));
        actions
    }

    /// Uncontended expected service time for one micro-task (seconds).
    fn expected_service_secs(&self, bytes: u64, relay: bool, gpu: GpuId, topo: &Topology) -> f64 {
        let lat = topo.lat;
        let pcie = topo.pcie_capacity(gpu, self.dir);
        let fixed = (lat.dispatch_cpu_ns + lat.dma_setup_ns + lat.event_sync_ns) as f64 * 1e-9;
        let mut t = fixed + bytes as f64 / pcie;
        if relay {
            // Forwarding hop: NVLink stage + P2P launch.
            let nv = topo.capacity(topo.link(crate::topology::LinkKind::NvOut(gpu)));
            t += lat.p2p_setup_ns as f64 * 1e-9 + bytes as f64 / nv;
        }
        t
    }
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::h20x8;

    fn desc(bytes: u64) -> TransferDesc {
        TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), bytes)
    }

    fn flow_keys(acts: &[EngineAction]) -> Vec<u64> {
        acts.iter()
            .filter_map(|a| match a {
                EngineAction::StartFlow { key, .. } => Some(*key),
                _ => None,
            })
            .collect()
    }

    /// Tiny sequential executor: runs the engine's action graph to
    /// quiescence with synthetic 1 us flow times. Returns completion info.
    fn drain(
        e: &mut Engine,
        topo: &Topology,
        init: Vec<EngineAction>,
    ) -> Vec<(TransferId, u64, u64)> {
        let mut pending: std::collections::VecDeque<EngineAction> = init.into();
        let mut now = Time::ZERO;
        let mut completes = Vec::new();
        let mut steps = 0u32;
        while let Some(act) = pending.pop_front() {
            steps += 1;
            assert!(steps < 1_000_000, "engine action graph does not quiesce");
            match act {
                EngineAction::StartFlow { key, .. } => {
                    now = now + Time::from_us(1);
                    pending.extend(e.on_flow_done(now, key, topo));
                }
                EngineAction::RetireAt { gpu, key, at } => {
                    now = now.max(at);
                    pending.extend(e.on_retire(now, gpu, key, topo));
                }
                EngineAction::WakeAt { gpu, at } => {
                    now = now.max(at);
                    pending.extend(e.on_wake(now, gpu, topo));
                }
                EngineAction::TransferComplete {
                    transfer,
                    bytes_direct,
                    bytes_relay,
                } => completes.push((transfer, bytes_direct, bytes_relay)),
            }
        }
        completes
    }

    #[test]
    fn activate_splits_and_wakes_all_workers() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        let acts = e.activate(Time::ZERO, TransferId(0), desc(50_000_000), &topo);
        let wakes = acts
            .iter()
            .filter(|a| matches!(a, EngineAction::WakeAt { .. }))
            .count();
        assert_eq!(wakes, 8);
        assert!(!e.is_idle());
        assert_eq!(e.active_transfers(), 1);
    }

    #[test]
    fn wake_fills_outstanding_queue_to_depth() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        e.activate(Time::ZERO, TransferId(0), desc(50_000_000), &topo);
        let acts = e.on_wake(Time::ZERO, GpuId(0), &topo);
        // Two slots occupied; only the first chunk's DMA starts (the second
        // queues behind it on the PCIe lane).
        assert_eq!(e.queues[0].slots.len(), 2);
        assert_eq!(flow_keys(&acts).len(), 1);
        // Re-waking without retirement does nothing (queue full).
        assert!(e.on_wake(Time::ZERO, GpuId(0), &topo).is_empty());
    }

    #[test]
    fn lane_serializes_back_to_back() {
        let topo = h20x8();
        let cfg = MmaConfig {
            relay_gpus: Some(vec![]),
            ..Default::default()
        };
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        e.activate(Time::ZERO, TransferId(0), desc(20_000_000), &topo);
        let acts = e.on_wake(Time::ZERO, GpuId(0), &topo);
        let keys = flow_keys(&acts);
        assert_eq!(keys, vec![0]);
        // First chunk's flow completes → lane hands off to chunk 1 with the
        // warm turnaround latency, and chunk 0 goes to retirement.
        let acts = e.on_flow_done(Time::from_us(100), keys[0], &topo);
        let mut saw_next = false;
        let mut saw_retire = false;
        for a in &acts {
            match a {
                EngineAction::StartFlow { key, latency, .. } => {
                    assert_eq!(*key, 1);
                    assert_eq!(latency.ns(), topo.lat.dma_turnaround_ns);
                    saw_next = true;
                }
                EngineAction::RetireAt { key, .. } => {
                    assert_eq!(*key, 0);
                    saw_retire = true;
                }
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(saw_next && saw_retire);
    }

    #[test]
    fn relay_two_stage_uses_pcie_then_nvlink() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        e.activate(Time::ZERO, TransferId(0), desc(50_000_000), &topo);
        let acts = e.on_wake(Time::ZERO, GpuId(1), &topo);
        let keys = flow_keys(&acts);
        assert_eq!(keys.len(), 1);
        // Stage 1 lands on the relay's own PCIe lane.
        let EngineAction::StartFlow { ref path, .. } = acts[0] else {
            panic!()
        };
        let kinds: Vec<_> = path.iter().map(|l| topo.links[l.0 as usize].kind).collect();
        assert!(kinds.contains(&crate::topology::LinkKind::PcieH2D(GpuId(1))));
        // Stage 1 done → next queued stage-1 starts AND stage 2 launches
        // over NVLink to the target (two different lanes: dual pipeline).
        let acts2 = e.on_flow_done(Time::from_us(100), keys[0], &topo);
        let stage2 = acts2
            .iter()
            .find_map(|a| match a {
                EngineAction::StartFlow { key, path, .. } if *key == keys[0] => Some(path),
                _ => None,
            })
            .expect("stage 2 flow missing: {acts2:?}");
        let kinds2: Vec<_> = stage2.iter().map(|l| topo.links[l.0 as usize].kind).collect();
        assert!(kinds2.contains(&crate::topology::LinkKind::NvOut(GpuId(1))));
        assert!(kinds2.contains(&crate::topology::LinkKind::NvIn(GpuId(0))));
        // The other action is the next chunk's stage 1 on the PCIe lane.
        let next = acts2
            .iter()
            .find_map(|a| match a {
                EngineAction::StartFlow { key, path, .. } if *key != keys[0] => Some(path),
                _ => None,
            })
            .expect("queued stage 1 missing");
        let kinds3: Vec<_> = next.iter().map(|l| topo.links[l.0 as usize].kind).collect();
        assert!(kinds3.contains(&crate::topology::LinkKind::PcieH2D(GpuId(1))));
        // Stage 2 completion retires via the sync thread.
        let acts3 = e.on_flow_done(Time::from_us(200), keys[0], &topo);
        assert!(
            acts3
                .iter()
                .any(|a| matches!(a, EngineAction::RetireAt { key, .. } if *key == keys[0])),
            "{acts3:?}"
        );
    }

    #[test]
    fn full_transfer_direct_only_completes_with_split() {
        let topo = h20x8();
        let cfg = MmaConfig {
            relay_gpus: Some(vec![]), // direct only
            ..Default::default()
        };
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        let init = e.activate(Time::ZERO, TransferId(5), desc(8_000_000), &topo);
        let completes = drain(&mut e, &topo, init);
        assert_eq!(completes, vec![(TransferId(5), 8_000_000, 0)]);
        assert!(e.is_idle());
        assert_eq!(e.stats.transfers_completed, 1);
    }

    #[test]
    fn full_transfer_with_relays_splits_bytes() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        let init = e.activate(Time::ZERO, TransferId(2), desc(100_000_000), &topo);
        let completes = drain(&mut e, &topo, init);
        assert_eq!(completes.len(), 1);
        let (t, bd, br) = completes[0];
        assert_eq!(t, TransferId(2));
        assert_eq!(bd + br, 100_000_000);
        assert!(br > 0, "relays never used");
        assert!(e.is_idle());
    }

    #[test]
    fn d2h_transfer_completes() {
        let topo = h20x8();
        let mut e = Engine::new(1, Direction::D2H, MmaConfig::default(), 8);
        let d = TransferDesc::new(Direction::D2H, GpuId(3), NumaId(0), 40_000_000);
        let init = e.activate(Time::ZERO, TransferId(7), d, &topo);
        let completes = drain(&mut e, &topo, init);
        assert_eq!(completes.len(), 1);
        assert_eq!(completes[0].1 + completes[0].2, 40_000_000);
    }

    #[test]
    fn single_pipeline_limits_relay_to_one_inflight() {
        let topo = h20x8();
        let cfg = MmaConfig {
            dual_pipeline: false,
            ..Default::default()
        };
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        e.activate(Time::ZERO, TransferId(0), desc(100_000_000), &topo);
        e.on_wake(Time::ZERO, GpuId(3), &topo);
        assert_eq!(e.queues[3].slots.len(), 1, "single pipeline: one relay slot");
        let mut e2 = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        e2.activate(Time::ZERO, TransferId(0), desc(100_000_000), &topo);
        e2.on_wake(Time::ZERO, GpuId(3), &topo);
        assert_eq!(e2.queues[3].slots.len(), 2, "dual pipeline: two relay slots");
    }

    #[test]
    fn static_policy_assigns_by_ratio() {
        let topo = h20x8();
        let cfg = MmaConfig {
            policy: crate::policy::PolicySpec::Static(vec![(GpuId(0), 1.0), (GpuId(1), 2.0)]),
            ..Default::default()
        };
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        // 30 MB → 6 chunks; 1:2 split → 2 direct on gpu0, 4 relayed by gpu1.
        let init = e.activate(Time::ZERO, TransferId(0), desc(30_000_000), &topo);
        let completes = drain(&mut e, &topo, init);
        assert_eq!(completes.len(), 1);
        assert_eq!(e.stats.chunks_dispatched[0], 2);
        assert_eq!(e.stats.chunks_dispatched[1], 4);
        assert_eq!(completes[0].1, 10_000_000); // direct bytes
        assert_eq!(completes[0].2, 20_000_000); // relay bytes
    }

    #[test]
    fn qos_critical_chunks_issue_before_earlier_bulk_ones() {
        // Same destination, bulk transfer activated first: with QoS on the
        // later latency-critical transfer's chunks pull first and it
        // completes first; with QoS off, FIFO lets the bulk one win.
        let topo = h20x8();
        let run = |qos_on: bool| {
            let mut cfg = MmaConfig {
                relay_gpus: Some(vec![]), // direct-only: one queue, clear ordering
                ..Default::default()
            };
            cfg.qos.enabled = qos_on;
            let mut e = Engine::new(0, Direction::H2D, cfg, 8);
            let bulk = desc(30_000_000).with_class(super::TransferClass::Bulk);
            let crit = desc(30_000_000).with_class(super::TransferClass::LatencyCritical);
            let mut init = e.activate(Time::ZERO, TransferId(0), bulk, &topo);
            init.extend(e.activate(Time::ZERO, TransferId(1), crit, &topo));
            let completes = drain(&mut e, &topo, init);
            assert_eq!(completes.len(), 2);
            completes[0].0 // first transfer to finish
        };
        assert_eq!(run(false), TransferId(0), "FIFO: earlier bulk transfer first");
        assert_eq!(run(true), TransferId(1), "QoS: critical transfer leapfrogs");
    }

    #[test]
    fn qos_throttles_bulk_to_one_outstanding_slot_while_critical_live() {
        let topo = h20x8();
        let mut cfg = MmaConfig {
            relay_gpus: Some(vec![]),
            ..Default::default()
        };
        cfg.qos.enabled = true;
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        // Bulk work for gpu0, critical work pending for gpu1: gpu0's queue
        // takes one bulk chunk and then stops (depth throttle) instead of
        // filling both slots.
        e.activate(
            Time::ZERO,
            TransferId(0),
            desc(40_000_000).with_class(super::TransferClass::Bulk),
            &topo,
        );
        e.activate(
            Time::ZERO,
            TransferId(1),
            TransferDesc::new(Direction::H2D, GpuId(1), NumaId(0), 40_000_000)
                .with_class(super::TransferClass::LatencyCritical),
            &topo,
        );
        e.on_wake(Time::ZERO, GpuId(0), &topo);
        assert_eq!(
            e.queues[0].slots.len(),
            1,
            "bulk capped at one slot while critical work is live"
        );
        // Without live critical work the same wake fills the full depth.
        let mut cfg2 = MmaConfig {
            relay_gpus: Some(vec![]),
            ..Default::default()
        };
        cfg2.qos.enabled = true;
        let mut e2 = Engine::new(0, Direction::H2D, cfg2, 8);
        e2.activate(
            Time::ZERO,
            TransferId(0),
            desc(40_000_000).with_class(super::TransferClass::Bulk),
            &topo,
        );
        e2.on_wake(Time::ZERO, GpuId(0), &topo);
        assert_eq!(e2.queues[0].slots.len(), 2, "no critical work → full depth");
    }

    #[test]
    fn qos_lane_queue_reorders_waiting_flows_by_class() {
        // Force two waiting descriptors behind an active copy on gpu0's
        // PCIe lane; under QoS the critical one must launch first when the
        // lane frees even though the bulk one queued earlier.
        let topo = h20x8();
        let mut cfg = MmaConfig {
            relay_gpus: Some(vec![]),
            outstanding_depth: 3,
            ..Default::default()
        };
        cfg.qos.enabled = true;
        let mut e = Engine::new(0, Direction::H2D, cfg, 8);
        // One critical chunk (launches, occupies the lane), then a bulk
        // and another critical transfer whose chunks queue behind it.
        e.activate(
            Time::ZERO,
            TransferId(0),
            desc(5_000_000).with_class(super::TransferClass::LatencyCritical),
            &topo,
        );
        let acts = e.on_wake(Time::ZERO, GpuId(0), &topo);
        let first = flow_keys(&acts);
        assert_eq!(first.len(), 1, "one active copy on the lane");
        e.activate(
            Time::ZERO,
            TransferId(1),
            desc(5_000_000).with_class(super::TransferClass::Bulk),
            &topo,
        );
        e.on_wake(Time::ZERO, GpuId(0), &topo);
        e.activate(
            Time::ZERO,
            TransferId(2),
            desc(5_000_000).with_class(super::TransferClass::LatencyCritical),
            &topo,
        );
        e.on_wake(Time::ZERO, GpuId(0), &topo);
        // Lane frees → the *critical* waiter launches, not the bulk one
        // that queued first.
        let acts = e.on_flow_done(Time::from_us(200), first[0], &topo);
        let next = acts
            .iter()
            .find_map(|a| match a {
                EngineAction::StartFlow { key, .. } => Some(*key),
                _ => None,
            })
            .expect("lane hand-off");
        let nxt = e.inflight[&next];
        assert_eq!(nxt.class, super::TransferClass::LatencyCritical);
        assert_eq!(nxt.chunk.transfer, TransferId(2));
    }

    #[test]
    fn contention_marks_backs_off_and_clears() {
        let topo = h20x8();
        let mut e = Engine::new(0, Direction::H2D, MmaConfig::default(), 8);
        e.activate(Time::ZERO, TransferId(0), desc(40_000_000), &topo);
        let acts = e.on_wake(Time::ZERO, GpuId(0), &topo);
        let k0 = flow_keys(&acts)[0];
        // Deliver chunk 0 absurdly late → contended on retire.
        let acts = e.on_flow_done(Time::from_ms(50), k0, &topo);
        let k1 = flow_keys(&acts)[0]; // queued chunk launches
        let EngineAction::RetireAt { gpu, key, at } = acts
            .iter()
            .find(|a| matches!(a, EngineAction::RetireAt { .. }))
            .cloned()
            .unwrap()
        else {
            panic!()
        };
        e.on_retire(at, gpu, key, &topo);
        assert!(e.queues[0].contended);
        assert_eq!(e.stats.backoff_events[0], 1);
        // Chunk 1 also late → still contended; queue now has 1 slot free
        // but backoff caps effective depth at 1 → pulls only one chunk.
        let acts = e.on_flow_done(Time::from_ms(51), k1, &topo);
        let EngineAction::RetireAt { gpu, key, at } = acts
            .iter()
            .find(|a| matches!(a, EngineAction::RetireAt { .. }))
            .cloned()
            .unwrap()
        else {
            panic!()
        };
        let retire_acts = e.on_retire(at, gpu, key, &topo);
        let wake_at = at;
        assert!(e.queues[0].contended);
        // Retirement inlines the worker wake: the pull happens right in
        // the returned actions — exactly one chunk under backoff.
        let keys = flow_keys(&retire_acts);
        assert_eq!(keys.len(), 1, "backoff must reduce depth to 1");
        assert_eq!(e.queues[0].slots.len(), 1);
        // On-time delivery clears the contention mark.
        let (k2, lat2, b2) = retire_acts
            .iter()
            .find_map(|a| match a {
                EngineAction::StartFlow { key, latency, bytes, .. } => {
                    Some((*key, *latency, *bytes))
                }
                _ => None,
            })
            .unwrap();
        let on_time = wake_at + lat2 + Time::from_secs_f64(b2 as f64 / 53.6e9);
        let acts = e.on_flow_done(on_time, k2, &topo);
        let EngineAction::RetireAt { gpu, key, at } = acts
            .iter()
            .find(|a| matches!(a, EngineAction::RetireAt { .. }))
            .cloned()
            .unwrap()
        else {
            panic!()
        };
        e.on_retire(at, gpu, key, &topo);
        assert!(!e.queues[0].contended, "clean completion must clear backoff");
    }
}
