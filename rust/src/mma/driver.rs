//! The composed simulation world: fabric + gpusim + MMA engines + event
//! loop. This is the executable model of one multi-GPU server running MMA
//! (or the native/static baselines) — every figure harness, integration
//! test, and the serving layer's transfer clock run through [`SimWorld`].

use super::engine::{ActionSink, Engine, EngineAction};
use super::interceptor::{self, Route};
use super::sync_engine::SyncEngine;
use super::transfer_task::{
    SubmitKind, TransferClass, TransferDesc, TransferRec, TransferState, NUM_CLASSES,
};
use super::{MmaConfig, QosConfig};
use crate::fabric::{Fabric, FlowDone, PathId};
use crate::gpusim::{Action, GpuSim, StreamId, StreamTask, TransferId};
use crate::sim::{EventQueue, Time};
use crate::topology::{Direction, GpuId, Topology};
use crate::util::SmallPath;
use std::collections::VecDeque;

/// Flow-tag layout: `[class:8][kind:8][a:24][b:24]` (`class` is the
/// [`TransferClass`] id).
mod tag {
    pub const KIND_CHUNK: u8 = 0;
    pub const KIND_NATIVE: u8 = 1;
    pub const KIND_BG: u8 = 2;
    /// Non-terminal relay stage (excluded from delivered-bandwidth sampling).
    pub const KIND_CHUNK_MID: u8 = 3;

    pub fn pack(class: u8, kind: u8, a: u32, b: u32) -> u64 {
        ((class as u64) << 56)
            | ((kind as u64) << 48)
            | (((a as u64) & 0xFF_FFFF) << 24)
            | ((b as u64) & 0xFF_FFFF)
    }
    pub fn class(t: u64) -> u8 {
        (t >> 56) as u8
    }
    pub fn kind(t: u64) -> u8 {
        (t >> 48) as u8
    }
    pub fn a(t: u64) -> u32 {
        ((t >> 24) & 0xFF_FFFF) as u32
    }
    pub fn b(t: u64) -> u32 {
        (t & 0xFF_FFFF) as u32
    }
}

/// Driver events.
#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Poll the fabric (flows activate/complete).
    Fabric,
    /// Wake engine `e`'s worker for `gpu`.
    EngineWake { e: u8, gpu: GpuId },
    /// Engine `e`'s sync thread retires chunk `key` on `gpu`'s queue.
    Retire { e: u8, gpu: GpuId, key: u64 },
    /// A kernel at the head of (dev, stream) finished. `tag` != 0 emits a
    /// [`Notice::KernelDone`].
    KernelDone {
        dev: GpuId,
        stream: StreamId,
        tag: u64,
    },
    /// A spin kernel observed its flag (one PCIe RTT after the set).
    SpinRelease {
        dev: GpuId,
        stream: StreamId,
        transfer: TransferId,
    },
    /// Periodic bandwidth sampling (Fig 9 time series).
    Sample,
    /// Background copy loop `id` starts its next iteration.
    BgNext { id: u32 },
    /// A user timer scheduled via [`SimWorld::schedule_timer`] fires.
    Timer { token: u64 },
}

/// A completion notification for external consumers of the event loop
/// (the serving layer is the main one). Notices are queued as the
/// simulation advances and drained via [`SimWorld::next_notice`]; nothing
/// in the driver depends on them being consumed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Notice {
    /// A submitted transfer's payload finished landing (same instant as
    /// `TransferRec::completed`; for async copies the downstream stream is
    /// released one PCIe RTT later).
    TransferDone(TransferId),
    /// A timer scheduled with [`SimWorld::schedule_timer`] fired.
    Timer(u64),
    /// A kernel enqueued with [`SimWorld::enqueue_kernel_tagged`] (nonzero
    /// tag) finished.
    KernelDone(u64),
}

/// A stream handle returned by [`SimWorld::stream`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamHandle {
    /// Device owning the stream.
    pub dev: GpuId,
    /// Stream id on that device.
    pub id: StreamId,
}

/// One bandwidth sample: time + per-class instantaneous rates (B/s).
#[derive(Clone, Debug)]
pub struct Sample {
    /// Sample time.
    pub at: Time,
    /// `rates[c]` = aggregate delivered rate of [`TransferClass`] id `c`.
    pub rates: [f64; NUM_CLASSES],
}

/// A background copy loop: back-to-back DMA on a fixed path (emulating
/// third-party traffic such as NIC DMA or a co-running native app).
struct BgLoop {
    /// Interned route: each iteration restarts the flow by id, so
    /// steady-state background traffic allocates nothing.
    path: PathId,
    bytes: u64,
    remaining: u64,
    class: TransferClass,
    latency: Time,
    /// Completion time of each finished iteration.
    iters: Vec<Time>,
    stopped: bool,
}

/// The composed world. See module docs.
pub struct SimWorld {
    /// Server topology.
    pub topo: Topology,
    /// Interconnect simulator.
    pub fabric: Fabric,
    /// CUDA execution model.
    pub gpus: GpuSim,
    engines: Vec<Engine>,
    sync: SyncEngine,
    q: EventQueue<Ev>,
    /// All transfers ever submitted (index = `TransferId.0`).
    pub transfers: Vec<TransferRec>,
    bg: Vec<BgLoop>,
    /// Collected bandwidth samples (if sampling enabled).
    pub samples: Vec<Sample>,
    sample_every: Option<Time>,
    sample_until: Time,
    /// Cumulative payload bytes delivered per class (terminal stages only).
    class_delivered: [f64; NUM_CLASSES],
    last_sampled: ([f64; NUM_CLASSES], Time),
    /// Pending completion notices for external consumers.
    notices: VecDeque<Notice>,
    /// Reused buffer for fabric completion harvesting (`Fabric::poll_into`),
    /// so the per-event hot path stays allocation-free.
    flow_done_scratch: Vec<FlowDone>,
    /// Reused action buffer for every engine call (`*_into` entry points):
    /// taken out, cleared, filled, applied, and put back — the per-event
    /// engine path never allocates a fresh `Vec<EngineAction>`.
    action_scratch: ActionSink,
    /// Fabric-level QoS parameters (per-class weights and the bulk cap):
    /// every flow this world launches — engine chunks, native copies,
    /// background loops — carries its class's weight onto the fabric.
    /// Taken from the founding process's [`MmaConfig::qos`]; later
    /// [`Self::add_process`] calls share the same fabric QoS domain.
    qos: QosConfig,
}

impl SimWorld {
    /// Build a world with one MMA "process" (an H2D + D2H engine pair)
    /// configured by `cfg`.
    pub fn new(topo: Topology, cfg: MmaConfig) -> SimWorld {
        let n = topo.gpu_count();
        let fabric = Fabric::new(&topo)
            .with_incremental(cfg.incremental_alloc)
            .with_coalesce(cfg.coalesce_solves);
        let qos = cfg.qos;
        SimWorld {
            fabric,
            gpus: GpuSim::new(n),
            engines: vec![
                Engine::new(0, Direction::H2D, cfg.clone(), n),
                Engine::new(1, Direction::D2H, cfg, n),
            ],
            sync: SyncEngine::new(),
            q: EventQueue::new(),
            transfers: Vec::new(),
            bg: Vec::new(),
            samples: Vec::new(),
            sample_every: None,
            sample_until: Time::ZERO,
            class_delivered: [0.0; NUM_CLASSES],
            last_sampled: ([0.0; NUM_CLASSES], Time::ZERO),
            notices: VecDeque::new(),
            flow_done_scratch: Vec::new(),
            action_scratch: ActionSink::new(),
            qos,
            topo,
        }
    }

    /// The world's fabric-level QoS parameters.
    pub fn qos(&self) -> &QosConfig {
        &self.qos
    }

    /// Add another MMA process (its own queues and pull scheduler sharing
    /// the same physical fabric — Fig 9b). Returns the process index.
    ///
    /// QoS is a property of the shared fabric, not of one process: the
    /// world has a single QoS domain (the founding process's
    /// [`MmaConfig::qos`]), so the added process's `cfg.qos` is replaced
    /// with the world's. This keeps the fabric weights and the engine's
    /// class-aware ordering consistent instead of silently half-enabling
    /// QoS for one process.
    pub fn add_process(&mut self, mut cfg: MmaConfig) -> u8 {
        cfg.qos = self.qos;
        let n = self.topo.gpu_count();
        let base = self.engines.len() as u8;
        self.engines
            .push(Engine::new(base, Direction::H2D, cfg.clone(), n));
        self.engines
            .push(Engine::new(base + 1, Direction::D2H, cfg, n));
        (base / 2) as u8
    }

    /// Current virtual time.
    pub fn now(&self) -> Time {
        self.q.now()
    }

    /// Engine instance for a process/direction (stats access).
    pub fn engine(&self, process: u8, dir: Direction) -> &Engine {
        let idx = process as usize * 2 + matches!(dir, Direction::D2H) as usize;
        &self.engines[idx]
    }

    /// Name of process 0's transfer policy (what `mma serve` reports).
    pub fn policy_name(&self) -> &'static str {
        self.engines[0].cfg.policy.name()
    }

    /// Create a stream on a device.
    pub fn stream(&mut self, dev: GpuId) -> StreamHandle {
        StreamHandle {
            dev,
            id: self.gpus.create_stream(dev),
        }
    }

    /// `cudaMemcpyAsync` through the interceptor, on process 0.
    pub fn memcpy_async(&mut self, s: StreamHandle, desc: TransferDesc) -> TransferId {
        self.memcpy_async_on(0, s, desc)
    }

    /// `cudaMemcpyAsync` through a specific process's interceptor.
    pub fn memcpy_async_on(
        &mut self,
        process: u8,
        s: StreamHandle,
        desc: TransferDesc,
    ) -> TransferId {
        self.submit_on(process, Some(s), desc)
    }

    /// `cudaMemcpyPeerAsync`: copy `bytes` from `src`'s HBM into the
    /// stream's device over the NVLink fabric. Never intercepted (§3.2);
    /// completion surfaces as a [`Notice::TransferDone`] like any copy.
    pub fn p2p_async(&mut self, s: StreamHandle, src: GpuId, bytes: u64) -> TransferId {
        self.memcpy_async(s, TransferDesc::p2p(src, s.dev, bytes))
    }

    /// Serving-layer fetch-path decision surface: should a prefix resident
    /// in sibling `src`'s HBM be fetched peer-to-peer over NVLink instead
    /// of from the host tier? Delegates to the configured
    /// [`crate::policy::TransferPolicy`] of process 0's H2D engine;
    /// `class` lets the policy route bulk traffic off PCIe even where the
    /// peer path is slower.
    pub fn prefer_peer_fetch(
        &self,
        src: GpuId,
        dst: GpuId,
        bytes: u64,
        class: TransferClass,
    ) -> bool {
        self.engines[0]
            .policy()
            .prefer_peer_fetch(&self.topo, src, dst, bytes, class)
    }

    /// `cudaMemcpy` (synchronous): starts immediately, bypassing streams.
    /// Use [`Self::run_until_transfer`] to emulate the blocked caller.
    pub fn memcpy_sync(&mut self, desc: TransferDesc) -> TransferId {
        self.memcpy_sync_on(0, desc)
    }

    /// Synchronous copy through a specific process.
    pub fn memcpy_sync_on(&mut self, process: u8, desc: TransferDesc) -> TransferId {
        self.submit_on(process, None, desc)
    }

    /// The one submit path every copy takes — async (`stream` set) and
    /// sync (`stream == None`) share the interceptor route, record
    /// bookkeeping, fallback stats, and class plumbing, so the two
    /// submission flavors cannot drift apart.
    fn submit_on(
        &mut self,
        process: u8,
        stream: Option<StreamHandle>,
        desc: TransferDesc,
    ) -> TransferId {
        let now = self.now();
        let engine_idx = process as usize * 2 + matches!(desc.dir, Direction::D2H) as usize;
        let tid = TransferId(self.transfers.len() as u32);
        let route = interceptor::route(&self.engines[engine_idx].cfg, &desc);
        let (kind, state, activated) = match stream {
            Some(s) => (SubmitKind::Async { stream: s.id }, TransferState::Recorded, None),
            None => (SubmitKind::Sync, TransferState::Active, Some(now)),
        };
        let mut rec = TransferRec {
            id: tid,
            desc,
            kind,
            engine: Some(engine_idx as u8),
            flag: None,
            state,
            submitted: now,
            activated,
            completed: None,
            released: None,
            bytes_direct: 0,
            bytes_relay: 0,
        };
        if route == Route::Native {
            rec.engine = None;
            if desc.peer.is_none() {
                // Peer copies are categorically native, not fallbacks.
                self.engines[engine_idx].stats.fallback_transfers += 1;
            }
        }
        match (route, stream) {
            (Route::Engine, Some(s)) => {
                // Async engine copy: a Dummy Task holds the stream; the
                // engine activates when it reaches its copy point.
                let flag = self
                    .sync
                    .install_dummy_task(&mut self.gpus, s.dev, s.id, tid);
                rec.flag = Some(flag);
                self.transfers.push(rec);
            }
            (Route::Engine, None) => {
                // Sync engine copy: the copy point is active immediately.
                self.transfers.push(rec);
                self.engine_activate(now, engine_idx as u8, tid, desc);
            }
            (Route::Native, Some(s)) => {
                self.transfers.push(rec);
                self.gpus
                    .enqueue(s.dev, s.id, StreamTask::Memcpy { transfer: tid });
            }
            (Route::Native, None) => {
                self.transfers.push(rec);
                self.start_native_flow(now, tid);
            }
        }
        if let Some(s) = stream {
            self.advance_stream(now, s.dev, s.id);
        }
        tid
    }

    /// Enqueue a compute kernel on a stream.
    pub fn enqueue_kernel(&mut self, s: StreamHandle, dur: Time, label: &'static str) {
        self.enqueue_kernel_tagged(s, dur, label, 0);
    }

    /// Enqueue a compute kernel whose completion is surfaced as a
    /// [`Notice::KernelDone`] carrying `tag` (must be nonzero to notify).
    pub fn enqueue_kernel_tagged(
        &mut self,
        s: StreamHandle,
        dur: Time,
        label: &'static str,
        tag: u64,
    ) {
        let now = self.now();
        self.gpus
            .enqueue(s.dev, s.id, StreamTask::Kernel { dur, label, tag });
        self.advance_stream(now, s.dev, s.id);
    }

    /// Schedule a [`Notice::Timer`] to fire at `at` (clamped to `now`).
    /// Lets external consumers (request arrivals in the serving layer)
    /// inject wake-ups into the one shared event loop.
    pub fn schedule_timer(&mut self, at: Time, token: u64) {
        self.q.schedule_at(at, Ev::Timer { token });
    }

    /// Start a background copy loop: `repeat` back-to-back copies of
    /// `bytes` over `path` (native-style single flows). Returns the loop id.
    pub fn start_bg_loop(
        &mut self,
        path: impl Into<SmallPath>,
        bytes: u64,
        repeat: u64,
        class: TransferClass,
    ) -> u32 {
        let path: SmallPath = path.into();
        let path = self.fabric.intern_path(&path);
        let id = self.bg.len() as u32;
        let latency = Time::from_ns(self.topo.lat.dma_setup_ns);
        self.bg.push(BgLoop {
            path,
            bytes,
            remaining: repeat,
            class,
            latency,
            iters: Vec::new(),
            stopped: false,
        });
        let now = self.now();
        self.q.schedule_at(now, Ev::BgNext { id });
        id
    }

    /// Stop a background loop after its current iteration.
    pub fn stop_bg_loop(&mut self, id: u32) {
        self.bg[id as usize].stopped = true;
    }

    /// Completion times of a background loop's finished iterations.
    pub fn bg_iters(&self, id: u32) -> &[Time] {
        &self.bg[id as usize].iters
    }

    /// Enable periodic per-class bandwidth sampling until `until`.
    pub fn enable_sampling(&mut self, every: Time, until: Time) {
        self.sample_every = Some(every);
        self.sample_until = until;
        let now = self.now();
        self.q.schedule_at(now + every, Ev::Sample);
    }

    /// Transfer record.
    pub fn rec(&self, t: TransferId) -> &TransferRec {
        &self.transfers[t.0 as usize]
    }

    /// Run until no events remain (all submitted work finished).
    pub fn run_until_idle(&mut self) -> Time {
        while self.step() {}
        let now = self.now();
        for e in &mut self.engines {
            e.stats.finish(now);
        }
        now
    }

    /// Run until `t` (events after `t` stay queued).
    pub fn run_until(&mut self, t: Time) {
        loop {
            match self.q.peek_time() {
                Some(next) if next <= t => {
                    self.step();
                }
                _ => break,
            }
        }
    }

    /// Run until a specific transfer completes; returns completion time.
    /// Panics if the world idles first (transfer can never finish).
    pub fn run_until_transfer(&mut self, t: TransferId) -> Time {
        loop {
            if let Some(done) = self.transfers[t.0 as usize].completed {
                return done;
            }
            assert!(self.step(), "world idle but {t:?} incomplete");
        }
    }

    /// Run until *any* of `ids` completes; returns the first found complete
    /// (in `ids` order among those done at that instant), or `None` if the
    /// world idles before any of them finishes.
    pub fn run_until_any(&mut self, ids: &[TransferId]) -> Option<TransferId> {
        loop {
            if let Some(&t) = ids
                .iter()
                .find(|t| self.transfers[t.0 as usize].completed.is_some())
            {
                return Some(t);
            }
            if !self.step() {
                return None;
            }
        }
    }

    /// Run until *all* of `ids` complete; returns the latest completion
    /// time (or `now` for an empty set). Panics if the world idles first.
    pub fn run_until_transfers(&mut self, ids: &[TransferId]) -> Time {
        let mut done = self.now();
        for &t in ids {
            done = done.max(self.run_until_transfer(t));
        }
        done
    }

    /// Advance the world until a completion notice is available and return
    /// it; `None` once the world is idle with no notices left. This is the
    /// pump external event consumers (the serving engine) are built on.
    pub fn next_notice(&mut self) -> Option<Notice> {
        loop {
            if let Some(n) = self.notices.pop_front() {
                return Some(n);
            }
            if !self.step() {
                return None;
            }
        }
    }

    // ----- internals ---------------------------------------------------

    fn step(&mut self) -> bool {
        self.arm_fabric();
        let Some((now, ev)) = self.q.pop() else {
            return false;
        };
        match ev {
            Ev::Fabric => {
                let mut done = std::mem::take(&mut self.flow_done_scratch);
                done.clear();
                self.fabric.poll_into(now, &mut done);
                for d in done.drain(..) {
                    self.route_flow_done(now, d);
                }
                self.flow_done_scratch = done;
            }
            Ev::EngineWake { e, gpu } => {
                let mut sink = std::mem::take(&mut self.action_scratch);
                sink.clear();
                self.engines[e as usize].on_wake_into(now, gpu, &self.topo, &mut sink);
                self.apply(now, e, &mut sink);
                self.action_scratch = sink;
            }
            Ev::Retire { e, gpu, key } => {
                let mut sink = std::mem::take(&mut self.action_scratch);
                sink.clear();
                self.engines[e as usize].on_retire_into(now, gpu, key, &self.topo, &mut sink);
                self.apply(now, e, &mut sink);
                self.action_scratch = sink;
            }
            Ev::KernelDone { dev, stream, tag } => {
                self.gpus.complete_head(dev, stream);
                if tag != 0 {
                    self.notices.push_back(Notice::KernelDone(tag));
                }
                self.advance_stream(now, dev, stream);
            }
            Ev::SpinRelease { dev, stream, transfer } => {
                self.gpus.release_spin(dev, stream);
                self.transfers[transfer.0 as usize].released = Some(now);
                self.advance_stream(now, dev, stream);
            }
            Ev::Sample => {
                // Windowed delivered-bytes rate per class: payload bytes
                // that landed at their destination since the last sample.
                // (Instantaneous link rates would double-count relay
                // stages and flicker with micro-burst drains.)
                let (ref last, last_t) = self.last_sampled;
                let dt = now.since(last_t).as_secs_f64().max(1e-12);
                let mut rates = [0.0f64; NUM_CLASSES];
                for c in 0..NUM_CLASSES {
                    rates[c] = (self.class_delivered[c] - last[c]) / dt;
                }
                self.last_sampled = (self.class_delivered, now);
                self.samples.push(Sample { at: now, rates });
                if let Some(every) = self.sample_every {
                    if now + every <= self.sample_until {
                        self.q.schedule_at(now + every, Ev::Sample);
                    }
                }
            }
            Ev::BgNext { id } => {
                let lp = &mut self.bg[id as usize];
                if lp.remaining > 0 && !lp.stopped {
                    lp.remaining -= 1;
                    let class = lp.class;
                    let t = tag::pack(class.id(), tag::KIND_BG, 0, id);
                    let (path, bytes, latency) = (lp.path, lp.bytes, lp.latency);
                    let (w, cap) = (self.qos.weight(class), self.qos.cap(class));
                    self.fabric.start_flow_path(now, path, bytes, latency, t, w, cap);
                }
            }
            Ev::Timer { token } => {
                self.notices.push_back(Notice::Timer(token));
            }
        }
        self.arm_fabric();
        true
    }

    /// Keep a fabric poll event scheduled at the fabric's next change.
    fn arm_fabric(&mut self) {
        if let Some(t) = self.fabric.next_event_time() {
            // Harmless over-scheduling: stale Fabric events are idempotent.
            match self.q.peek_time() {
                Some(head) if head <= t => {} // something earlier already queued
                _ => self.q.schedule_at(t, Ev::Fabric),
            }
        }
    }

    fn route_flow_done(&mut self, now: Time, d: FlowDone) {
        if tag::kind(d.tag) != tag::KIND_CHUNK_MID {
            // Terminal stages only: relayed bytes count once.
            self.class_delivered[tag::class(d.tag) as usize % NUM_CLASSES] += d.bytes as f64;
        }
        match tag::kind(d.tag) {
            tag::KIND_CHUNK | tag::KIND_CHUNK_MID => {
                let e = tag::a(d.tag) as u8;
                let key = tag::b(d.tag) as u64;
                let mut sink = std::mem::take(&mut self.action_scratch);
                sink.clear();
                self.engines[e as usize].on_flow_done_into(now, key, &self.topo, &mut sink);
                self.apply(now, e, &mut sink);
                self.action_scratch = sink;
            }
            tag::KIND_NATIVE => {
                let tid = TransferId(tag::b(d.tag));
                let rec = &mut self.transfers[tid.0 as usize];
                rec.completed = Some(now);
                rec.released = Some(now);
                rec.state = TransferState::Complete;
                rec.bytes_direct += rec.desc.bytes;
                self.notices.push_back(Notice::TransferDone(tid));
                if let SubmitKind::Async { stream } = rec.kind {
                    let dev = rec.desc.gpu;
                    self.gpus.complete_head(dev, stream);
                    self.advance_stream(now, dev, stream);
                }
            }
            tag::KIND_BG => {
                let id = tag::b(d.tag);
                self.bg[id as usize].iters.push(now);
                self.q.schedule_at(now, Ev::BgNext { id });
            }
            k => panic!("unknown flow tag kind {k}"),
        }
    }

    /// Run one engine's `*_into` entry point through the shared
    /// [`Self::action_scratch`] sink and apply the resulting actions —
    /// the allocation-free replacement for collecting a `Vec` per event.
    fn engine_activate(&mut self, now: Time, e: u8, tid: TransferId, desc: TransferDesc) {
        let mut sink = std::mem::take(&mut self.action_scratch);
        sink.clear();
        self.engines[e as usize].activate_into(now, tid, desc, &self.topo, &mut sink);
        self.apply(now, e, &mut sink);
        self.action_scratch = sink;
    }

    fn apply(&mut self, now: Time, e: u8, sink: &mut ActionSink) {
        for a in sink.drain() {
            match a {
                EngineAction::StartFlow {
                    key,
                    path,
                    bytes,
                    latency,
                    class,
                    terminal,
                } => {
                    let kind = if terminal { tag::KIND_CHUNK } else { tag::KIND_CHUNK_MID };
                    let t = tag::pack(class.id(), kind, e as u32, key as u32);
                    let (w, cap) = (self.qos.weight(class), self.qos.cap(class));
                    self.fabric.start_flow_qos(now, &path, bytes, latency, t, w, cap);
                }
                EngineAction::WakeAt { gpu, at } => {
                    self.q.schedule_at(at, Ev::EngineWake { e, gpu });
                }
                EngineAction::RetireAt { gpu, key, at } => {
                    self.q.schedule_at(at, Ev::Retire { e, gpu, key });
                }
                EngineAction::TransferComplete {
                    transfer,
                    bytes_direct,
                    bytes_relay,
                } => {
                    let rec = &mut self.transfers[transfer.0 as usize];
                    rec.completed = Some(now);
                    rec.state = TransferState::Complete;
                    rec.bytes_direct = bytes_direct;
                    rec.bytes_relay = bytes_relay;
                    self.notices.push_back(Notice::TransferDone(transfer));
                    if let SubmitKind::Async { stream } = rec.kind {
                        let dev = rec.desc.gpu;
                        let rtt = Time::from_ns(self.topo.lat.pcie_rtt_ns);
                        let waiters = self.sync.complete(&mut self.gpus, transfer);
                        for (wdev, wstream) in waiters {
                            debug_assert_eq!((wdev, wstream), (dev, stream));
                            self.q.schedule_at(
                                now + rtt,
                                Ev::SpinRelease {
                                    dev: wdev,
                                    stream: wstream,
                                    transfer,
                                },
                            );
                        }
                    }
                }
            }
        }
    }

    fn advance_stream(&mut self, now: Time, dev: GpuId, stream: StreamId) {
        let actions = self.gpus.try_advance(now, dev, stream);
        for a in actions {
            match a {
                Action::KernelStarted {
                    dev,
                    stream,
                    dur,
                    tag,
                } => {
                    self.q
                        .schedule_at(now + dur, Ev::KernelDone { dev, stream, tag });
                }
                Action::CopyReachedHead { transfer, .. } => {
                    self.transfers[transfer.0 as usize].activated = Some(now);
                    self.start_native_flow(now, transfer);
                }
                Action::RunCallback { cb } => {
                    // The Dummy Task's copy point is active (§3.1 step ②).
                    let tid = self.sync.transfer_of(cb);
                    let rec = &mut self.transfers[tid.0 as usize];
                    rec.activated = Some(now);
                    rec.state = TransferState::Active;
                    let e = rec.engine.expect("callback for native transfer");
                    let desc = rec.desc;
                    self.engine_activate(now, e, tid, desc);
                }
                Action::SpinParked { .. } => {}
            }
        }
    }

    /// Launch the single direct-path DMA of a native (non-engine) copy:
    /// the host↔GPU direct path, or the NVLink P2P path for peer copies.
    fn start_native_flow(&mut self, now: Time, tid: TransferId) {
        let rec = &self.transfers[tid.0 as usize];
        let desc = rec.desc;
        let (path, latency) = match desc.peer {
            Some(src) => (
                self.topo.p2p(src, desc.gpu),
                Time::from_ns(self.topo.lat.p2p_setup_ns),
            ),
            None => {
                let p = match desc.dir {
                    Direction::H2D => self.topo.h2d_direct(desc.host_numa, desc.gpu),
                    Direction::D2H => self.topo.d2h_direct(desc.gpu, desc.host_numa),
                };
                (p, Time::from_ns(self.topo.lat.dma_setup_ns))
            }
        };
        let t = tag::pack(desc.class.id(), tag::KIND_NATIVE, 0, tid.0);
        let (w, cap) = (self.qos.weight(desc.class), self.qos.cap(desc.class));
        self.fabric.start_flow_qos(now, &path, desc.bytes, latency, t, w, cap);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{h20x8, NumaId};

    fn world(cfg: MmaConfig) -> SimWorld {
        SimWorld::new(h20x8(), cfg)
    }

    fn h2d(bytes: u64) -> TransferDesc {
        TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), bytes)
    }

    #[test]
    fn native_async_copy_runs_at_pcie_rate() {
        let mut w = world(MmaConfig::native());
        let s = w.stream(GpuId(0));
        let t = w.memcpy_async(s, h2d(1_000_000_000));
        let done = w.run_until_transfer(t);
        let bw = w.rec(t).bandwidth().unwrap();
        assert!((bw - 53.4e9).abs() < 0.5e9, "native bw {bw}");
        assert!(done.as_ms_f64() < 20.0);
    }

    #[test]
    fn mma_async_copy_beats_native_substantially() {
        let bytes = 2_000_000_000u64;
        let mut wn = world(MmaConfig::native());
        let sn = wn.stream(GpuId(0));
        let tn = wn.memcpy_async(sn, h2d(bytes));
        wn.run_until_transfer(tn);
        let native_bw = wn.rec(tn).bandwidth().unwrap();

        let mut wm = world(MmaConfig::default());
        let sm = wm.stream(GpuId(0));
        let tm = wm.memcpy_async(sm, h2d(bytes));
        wm.run_until_transfer(tm);
        let mma_bw = wm.rec(tm).bandwidth().unwrap();

        assert!(
            mma_bw > 3.0 * native_bw,
            "mma {mma_bw:.2e} vs native {native_bw:.2e}"
        );
        // Relay bytes dominate with 7 relays.
        let rec = wm.rec(tm);
        assert!(rec.bytes_relay > rec.bytes_direct);
        assert_eq!(rec.bytes_relay + rec.bytes_direct, bytes);
    }

    #[test]
    fn downstream_kernel_waits_for_mma_transfer() {
        let mut w = world(MmaConfig::default());
        let s = w.stream(GpuId(0));
        let t = w.memcpy_async(s, h2d(500_000_000));
        w.enqueue_kernel(s, Time::from_us(10), "consumer");
        w.run_until_idle();
        let rec = w.rec(t);
        let released = rec.released.expect("spin never released");
        let completed = rec.completed.unwrap();
        // Spin kernel releases one PCIe RTT after the flag set.
        assert_eq!(released.ns() - completed.ns(), w.topo.lat.pcie_rtt_ns);
        // The consumer kernel ran only after release: stream completed all
        // 3 tasks (callback, spin, kernel).
        assert_eq!(w.gpus.stream_completed(GpuId(0), s.id), 3);
    }

    #[test]
    fn small_copy_takes_fallback() {
        let mut w = world(MmaConfig::default());
        let s = w.stream(GpuId(0));
        let t = w.memcpy_async(s, h2d(1_000_000)); // 1 MB < 11.3 MB
        w.run_until_transfer(t);
        let rec = w.rec(t);
        assert_eq!(rec.bytes_relay, 0);
        assert_eq!(rec.bytes_direct, 1_000_000);
        assert_eq!(w.engine(0, Direction::H2D).stats.fallback_transfers, 1);
    }

    #[test]
    fn p2p_async_copy_runs_at_nvlink_rate() {
        // A peer copy rides the NVSwitch fabric: far above PCIe rates,
        // uncontended by host-path traffic, and it notifies on completion.
        let mut w = world(MmaConfig::default());
        let s1 = w.stream(GpuId(1));
        let t = w.p2p_async(s1, GpuId(0), 1 << 30);
        let done = w.run_until_transfer(t);
        let bw = w.rec(t).bandwidth().unwrap();
        assert!(bw > 300e9, "p2p bw {bw}");
        assert!(done.as_ms_f64() < 10.0);
        let rec = w.rec(t);
        assert_eq!(rec.bytes_direct, 1 << 30);
        assert_eq!(rec.bytes_relay, 0);
        // Never counted as an engine fallback (it is not a host copy).
        assert_eq!(w.engine(0, Direction::H2D).stats.fallback_transfers, 0);
        let mut got = Vec::new();
        while let Some(n) = w.next_notice() {
            got.push(n);
        }
        assert!(got.contains(&Notice::TransferDone(t)), "{got:?}");
    }

    #[test]
    fn prefer_peer_fetch_defaults_to_nvlink_on_h20() {
        // NVLink (368 GB/s) beats the PCIe lane (53.6 GB/s) on every
        // policy's default decision surface, for every traffic class.
        for cfg in [MmaConfig::native(), MmaConfig::default()] {
            let w = world(cfg);
            for class in TransferClass::ALL {
                assert!(w.prefer_peer_fetch(GpuId(0), GpuId(1), 1 << 30, class));
            }
        }
    }

    #[test]
    fn sync_copy_completes_without_stream() {
        let mut w = world(MmaConfig::default());
        let t = w.memcpy_sync(h2d(500_000_000));
        let done = w.run_until_transfer(t);
        assert!(done > Time::ZERO);
        assert!(w.rec(t).bandwidth().unwrap() > 100e9);
    }

    #[test]
    fn d2h_uses_engine_too() {
        let mut w = world(MmaConfig::default());
        let t = w.memcpy_sync(TransferDesc::new(
            Direction::D2H,
            GpuId(0),
            NumaId(0),
            1_000_000_000,
        ));
        w.run_until_transfer(t);
        let bw = w.rec(t).bandwidth().unwrap();
        assert!(bw > 150e9, "d2h mma bw {bw}");
    }

    #[test]
    fn stream_order_kernel_then_copy_then_kernel() {
        // The copy must not start until the preceding kernel finishes
        // (C1: stream FIFO), and the following kernel must wait (C2).
        let mut w = world(MmaConfig::default());
        let s = w.stream(GpuId(0));
        w.enqueue_kernel(s, Time::from_ms(2), "pre");
        let t = w.memcpy_async(s, h2d(200_000_000));
        w.enqueue_kernel(s, Time::from_us(1), "post");
        w.run_until_idle();
        let rec = w.rec(t);
        assert!(rec.activated.unwrap() >= Time::from_ms(2));
        assert!(rec.released.unwrap() > rec.activated.unwrap());
    }

    #[test]
    fn two_processes_share_fabric() {
        let mut w = world(MmaConfig::default());
        let p1 = w.add_process(MmaConfig::default());
        assert_eq!(p1, 1);
        let s0 = w.stream(GpuId(0));
        let s4 = w.stream(GpuId(4));
        let a = w.memcpy_async_on(0, s0, h2d(1_000_000_000));
        let b = w.memcpy_async_on(
            1,
            s4,
            TransferDesc::new(Direction::H2D, GpuId(4), NumaId(1), 1_000_000_000),
        );
        w.run_until_idle();
        let bwa = w.rec(a).bandwidth().unwrap();
        let bwb = w.rec(b).bandwidth().unwrap();
        // Both exceed native even while contending.
        assert!(bwa > 80e9, "{bwa}");
        assert!(bwb > 80e9, "{bwb}");
    }

    #[test]
    fn added_process_joins_the_worlds_qos_domain() {
        // QoS is fabric-global: a process added with a mismatched cfg.qos
        // is normalized onto the founding process's domain.
        let mut base = MmaConfig::default();
        base.qos.enabled = true;
        let mut w = world(base);
        let p = w.add_process(MmaConfig::default()); // its own qos is off
        assert!(w.qos().enabled);
        assert!(w.engine(p, Direction::H2D).cfg.qos.enabled);
        assert!(w.engine(p, Direction::D2H).cfg.qos.enabled);
    }

    #[test]
    fn bg_loop_iterates_and_stops() {
        let mut w = world(MmaConfig::native());
        let path = w.topo.h2d_direct(NumaId(0), GpuId(2));
        let id = w.start_bg_loop(path, 100_000_000, 5, TransferClass::Background);
        w.run_until_idle();
        assert_eq!(w.bg_iters(id).len(), 5);
    }

    #[test]
    fn notices_surface_transfers_timers_and_tagged_kernels() {
        let mut w = world(MmaConfig::native());
        let s = w.stream(GpuId(0));
        w.schedule_timer(Time::from_us(5), 42);
        let t = w.memcpy_async(s, h2d(1_000_000)); // ~19 us at native rate
        w.enqueue_kernel_tagged(s, Time::from_us(3), "consumer", 7);
        let mut got = Vec::new();
        while let Some(n) = w.next_notice() {
            got.push(n);
        }
        assert_eq!(got[0], Notice::Timer(42), "{got:?}");
        assert!(got.contains(&Notice::TransferDone(t)), "{got:?}");
        // Stream FIFO: the tagged kernel completes after the copy.
        assert_eq!(*got.last().unwrap(), Notice::KernelDone(7), "{got:?}");
    }

    #[test]
    fn untagged_kernels_do_not_notify() {
        let mut w = world(MmaConfig::native());
        let s = w.stream(GpuId(0));
        w.enqueue_kernel(s, Time::from_us(3), "quiet");
        assert_eq!(w.next_notice(), None);
        assert_eq!(w.gpus.stream_completed(GpuId(0), s.id), 1);
    }

    #[test]
    fn run_until_any_returns_first_completion() {
        let mut w = world(MmaConfig::native());
        let s0 = w.stream(GpuId(0));
        let s1 = w.stream(GpuId(1));
        let big = w.memcpy_async(s0, h2d(1_000_000_000));
        let small = w.memcpy_async(
            s1,
            TransferDesc::new(Direction::H2D, GpuId(1), NumaId(0), 1_000_000),
        );
        let first = w.run_until_any(&[big, small]).unwrap();
        assert_eq!(first, small);
        assert!(w.rec(big).completed.is_none(), "big must still be in flight");
        let all_done = w.run_until_transfers(&[big, small]);
        assert_eq!(all_done, w.rec(big).completed.unwrap());
    }

    #[test]
    fn qos_weights_protect_critical_native_flows() {
        // Two native copies share gpu0's PCIe lane: with QoS on, the
        // latency-critical one holds its 8/9 weighted share instead of the
        // unweighted half — the driver-level form of the bulk-wake vs
        // critical-fetch regression.
        let mut cfg = MmaConfig::native();
        cfg.qos.enabled = true;
        let mut w = world(cfg);
        let s0 = w.stream(GpuId(0));
        let s1 = w.stream(GpuId(0));
        let crit = w.memcpy_async(
            s0,
            h2d(1_000_000_000).with_class(TransferClass::LatencyCritical),
        );
        let bulk = w.memcpy_async(s1, h2d(1_000_000_000).with_class(TransferClass::Bulk));
        w.run_until_idle();
        let lane = w.topo.pcie_capacity(GpuId(0), Direction::H2D);
        let crit_bw = w.rec(crit).bandwidth().unwrap();
        let bulk_bw = w.rec(bulk).bandwidth().unwrap();
        // Weighted share 8/9 ≈ 47.6 GB/s until the critical copy lands.
        assert!(crit_bw > 0.8 * lane, "critical bw {crit_bw} vs lane {lane}");
        assert!(bulk_bw < 0.65 * lane, "bulk must yield: {bulk_bw}");
        assert!(w.rec(bulk).completed.is_some(), "bulk still completes");
    }

    #[test]
    fn qos_disabled_shares_the_lane_evenly_regardless_of_class() {
        // The degenerate case: with QoS off, class tags are labels only —
        // both copies get the unweighted fair half.
        let mut w = world(MmaConfig::native());
        let s0 = w.stream(GpuId(0));
        let s1 = w.stream(GpuId(0));
        let crit = w.memcpy_async(
            s0,
            h2d(1_000_000_000).with_class(TransferClass::LatencyCritical),
        );
        let bulk = w.memcpy_async(s1, h2d(1_000_000_000).with_class(TransferClass::Bulk));
        w.run_until_idle();
        let a = w.rec(crit).bandwidth().unwrap();
        let b = w.rec(bulk).bandwidth().unwrap();
        assert!((a - b).abs() < 0.02 * a, "equal halves expected: {a} vs {b}");
    }

    #[test]
    fn qos_bulk_cap_throttles_even_an_idle_fabric() {
        let mut cfg = MmaConfig::native();
        cfg.qos.enabled = true;
        cfg.qos.bulk_cap_bps = 10e9;
        let mut w = world(cfg);
        let s = w.stream(GpuId(0));
        let bulk = w.memcpy_async(s, h2d(1_000_000_000).with_class(TransferClass::Bulk));
        w.run_until_transfer(bulk);
        let bw = w.rec(bulk).bandwidth().unwrap();
        assert!(bw < 10.1e9, "capped bulk bw {bw}");
        // Critical traffic is never capped.
        let s2 = w.stream(GpuId(0));
        let crit = w.memcpy_async(
            s2,
            h2d(1_000_000_000).with_class(TransferClass::LatencyCritical),
        );
        w.run_until_transfer(crit);
        assert!(w.rec(crit).bandwidth().unwrap() > 50e9);
    }

    #[test]
    fn qos_engine_corun_favors_critical_transfer() {
        // Through the full multipath engine: equal-size critical and bulk
        // transfers to the same GPU submitted bulk-first. QoS on must
        // complete the critical transfer sooner than bulk; and sooner than
        // the critical one finishes under QoS off.
        let finish = |qos_on: bool| {
            let mut cfg = MmaConfig::default();
            cfg.qos.enabled = qos_on;
            let mut w = world(cfg);
            let bulk = w.memcpy_sync(h2d(400_000_000).with_class(TransferClass::Bulk));
            let crit =
                w.memcpy_sync(h2d(400_000_000).with_class(TransferClass::LatencyCritical));
            w.run_until_idle();
            (
                w.rec(crit).completed.unwrap(),
                w.rec(bulk).completed.unwrap(),
            )
        };
        let (crit_on, bulk_on) = finish(true);
        let (crit_off, _) = finish(false);
        assert!(
            crit_on < bulk_on,
            "critical must land first under QoS: {crit_on:?} vs {bulk_on:?}"
        );
        assert!(
            crit_on < crit_off,
            "QoS must speed up the critical transfer: {crit_on:?} vs {crit_off:?}"
        );
    }

    #[test]
    fn sampling_records_series() {
        let mut w = world(MmaConfig::default());
        w.enable_sampling(Time::from_us(200), Time::from_ms(20));
        let s = w.stream(GpuId(0));
        w.memcpy_async(s, h2d(1_000_000_000));
        w.run_until_idle();
        assert!(w.samples.len() > 10);
        let peak = w
            .samples
            .iter()
            .map(|s| s.rates[1])
            .fold(0.0f64, f64::max);
        assert!(peak > 100e9, "sampled peak {peak}");
    }
}
