//! Task Manager (§3.4.1): splits each transfer into fixed-size micro-tasks
//! and maintains the destination-tagged micro-task queue of Figure 5 —
//! now class-aware: every chunk carries its transfer's
//! [`TransferClass`], and under QoS the queues issue latency-critical
//! chunks ahead of bulk ones and refuse to steal bulk work onto paths
//! with queued critical chunks (the steal guard lives in exactly one
//! place: [`TaskManager::pop_steal_scored`]).

use super::transfer_task::{TransferClass, NUM_CLASSES};
use crate::gpusim::TransferId;
use crate::topology::GpuId;
use std::collections::VecDeque;

/// One micro-task: a fixed-size slice of a transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Chunk {
    /// Parent transfer.
    pub transfer: TransferId,
    /// Index within the transfer (0-based).
    pub index: u32,
    /// Size of this chunk (the tail chunk may be short).
    pub bytes: u64,
    /// Destination (H2D) or source (D2H) GPU — the "color" in Figure 5.
    pub dest: GpuId,
    /// The parent transfer's QoS class (issue priority + fabric weight).
    pub class: TransferClass,
}

/// How a pull round may treat transfer classes. The engine derives one per
/// worker wake-up from its QoS config and queue state; the all-false
/// default reproduces the pre-QoS FIFO behavior exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PullClassPolicy {
    /// Pop by class priority (latency-critical first, FIFO within a
    /// class) instead of strict FIFO. Set when QoS is enabled.
    pub by_class: bool,
    /// This round may only pull critical-band chunks — the bulk depth
    /// throttle: a queue already holding a bulk chunk in flight stops
    /// taking more bulk work while critical flows are live anywhere.
    pub critical_only: bool,
    /// This path holds an in-flight critical chunk: bulk-band chunks may
    /// not be *stolen* onto it (they would queue behind / contend with the
    /// critical work on the same lane).
    pub no_bulk_steal: bool,
}

/// Destination-tagged micro-task queue. Chunks of the same destination and
/// class keep FIFO order; `remaining_bytes` per destination drives the
/// longest-remaining-destination relay-stealing policy (§3.4.2).
pub struct TaskManager {
    pending: Vec<VecDeque<Chunk>>,
    /// Pending bytes per destination, all classes.
    remaining: Vec<u64>,
    /// Pending bytes per destination in the critical band
    /// (`LatencyCritical` + `Interactive`).
    remaining_crit: Vec<u64>,
    /// Statically pre-assigned chunks per path GPU (static-split baseline;
    /// not class-reordered — static splitting has no adaptive machinery).
    assigned: Vec<VecDeque<Chunk>>,
    total_pending: usize,
    /// Pending chunks per class across all destinations (the class-mix
    /// surface policies see through `PolicyView`).
    class_chunks: [u64; NUM_CLASSES],
}

impl TaskManager {
    /// Create for a server with `gpu_count` GPUs.
    pub fn new(gpu_count: usize) -> TaskManager {
        TaskManager {
            pending: (0..gpu_count).map(|_| VecDeque::new()).collect(),
            remaining: vec![0; gpu_count],
            remaining_crit: vec![0; gpu_count],
            assigned: (0..gpu_count).map(|_| VecDeque::new()).collect(),
            total_pending: 0,
            class_chunks: [0; NUM_CLASSES],
        }
    }

    /// Split `bytes` into `chunk_bytes`-sized micro-tasks of `class`. The
    /// tail chunk carries the remainder (never zero-sized).
    pub fn split(
        transfer: TransferId,
        dest: GpuId,
        bytes: u64,
        chunk_bytes: u64,
        class: TransferClass,
    ) -> Vec<Chunk> {
        let mut out = Vec::new();
        Self::split_into(transfer, dest, bytes, chunk_bytes, class, &mut out);
        out
    }

    /// [`TaskManager::split`] into a caller-owned buffer (cleared first),
    /// so activation can reuse one scratch `Vec` across transfers instead
    /// of allocating per call.
    pub fn split_into(
        transfer: TransferId,
        dest: GpuId,
        bytes: u64,
        chunk_bytes: u64,
        class: TransferClass,
        out: &mut Vec<Chunk>,
    ) {
        assert!(bytes > 0, "empty transfer");
        out.clear();
        let cb = chunk_bytes.max(1);
        let n = bytes.div_ceil(cb);
        out.extend((0..n).map(|i| {
            let off = i * cb;
            Chunk {
                transfer,
                index: i as u32,
                bytes: (bytes - off).min(cb),
                dest,
                class,
            }
        }));
    }

    /// Enqueue chunks into the destination-tagged queue (pull mode).
    pub fn push_pending(&mut self, chunks: &[Chunk]) {
        for c in chunks {
            self.pending[c.dest.0 as usize].push_back(*c);
            self.book_push(c);
        }
    }

    /// Enqueue a chunk onto a specific path GPU's assigned queue
    /// (static-split mode; no stealing ever happens from these).
    pub fn push_assigned(&mut self, path_gpu: GpuId, chunk: Chunk) {
        self.assigned[path_gpu.0 as usize].push_back(chunk);
        self.total_pending += 1;
    }

    /// Pop the next direct micro-task for `gpu` (dest == gpu). Under
    /// `cp.by_class` the highest-priority class pops first (FIFO within a
    /// class); `cp.critical_only` skips bulk-band chunks entirely.
    pub fn pop_direct(&mut self, gpu: GpuId, cp: PullClassPolicy) -> Option<Chunk> {
        let pos = self.select_pos(gpu, cp.by_class, cp.critical_only)?;
        let c = self.pending[gpu.0 as usize].remove(pos).expect("selected pos in range");
        self.book_pop(&c);
        Some(c)
    }

    /// Pop the next statically-assigned micro-task for path `gpu`.
    pub fn pop_assigned(&mut self, gpu: GpuId) -> Option<Chunk> {
        let c = self.assigned[gpu.0 as usize].pop_front()?;
        self.total_pending -= 1;
        Some(c)
    }

    /// Pop a relay micro-task for `gpu` from the destination with the
    /// highest `score(dest, stealable_bytes)`; `None` scores mark a
    /// destination ineligible, ties keep the lowest GPU index. This is the
    /// single scored steal every pull policy ranks with (longest-remaining
    /// is `|_, rem| Some(rem as f64)`; NUMA discounts and backlog
    /// thresholds layer on top) — and the one place the class-aware steal
    /// guard lives: when QoS is on and this path has queued or in-flight
    /// critical work (`cp.no_bulk_steal` / own pending critical direct
    /// chunks), or the round is `critical_only`, bulk-band chunks are not
    /// stealable and `stealable_bytes` counts only the critical band.
    pub fn pop_steal_scored(
        &mut self,
        gpu: GpuId,
        cp: PullClassPolicy,
        mut score: impl FnMut(GpuId, u64) -> Option<f64>,
    ) -> Option<Chunk> {
        let block_bulk = cp.by_class
            && (cp.critical_only || cp.no_bulk_steal || self.has_critical_direct(gpu));
        let mut best: Option<GpuId> = None;
        let mut best_score = 0.0f64;
        for d in 0..self.pending.len() {
            let dest = GpuId(d as u8);
            if dest == gpu {
                continue;
            }
            let stealable = if block_bulk {
                self.remaining_crit[d]
            } else {
                self.remaining[d]
            };
            if stealable == 0 {
                continue;
            }
            let Some(s) = score(dest, stealable) else {
                continue;
            };
            if s > best_score {
                best_score = s;
                best = Some(dest);
            }
        }
        let dest = best?;
        let pos = self
            .select_pos(dest, cp.by_class, block_bulk)
            .expect("stealable bytes imply an eligible chunk");
        let c = self.pending[dest.0 as usize].remove(pos).expect("selected pos in range");
        self.book_pop(&c);
        Some(c)
    }

    /// Remaining pending bytes for a destination.
    pub fn remaining_for(&self, dest: GpuId) -> u64 {
        self.remaining[dest.0 as usize]
    }

    /// Pending direct work available for `gpu`?
    pub fn has_direct(&self, gpu: GpuId) -> bool {
        !self.pending[gpu.0 as usize].is_empty()
    }

    /// Pending critical-band direct work for `gpu`?
    pub fn has_critical_direct(&self, gpu: GpuId) -> bool {
        self.remaining_crit[gpu.0 as usize] > 0
    }

    /// Pending critical-band chunks anywhere (the "critical flows are
    /// live" half of the engine's bulk depth throttle).
    pub fn critical_pending(&self) -> u64 {
        self.class_chunks[TransferClass::LatencyCritical as usize]
            + self.class_chunks[TransferClass::Interactive as usize]
    }

    /// Pending pull-mode chunks per class (the `PolicyView` class mix;
    /// statically-assigned chunks are excluded — they are already placed).
    pub fn pending_by_class(&self) -> [u64; NUM_CLASSES] {
        self.class_chunks
    }

    /// Any statically-assigned work for `gpu`?
    pub fn has_assigned(&self, gpu: GpuId) -> bool {
        !self.assigned[gpu.0 as usize].is_empty()
    }

    /// Total micro-tasks awaiting dispatch.
    pub fn pending_count(&self) -> usize {
        self.total_pending
    }

    /// True when no work is queued anywhere.
    pub fn is_empty(&self) -> bool {
        self.total_pending == 0
    }

    // ----- internals ---------------------------------------------------

    /// Position of the next chunk to pop from `dest`'s pending queue:
    /// front (FIFO) unless `by_class`, then the first occurrence of the
    /// most urgent class present; `critical_only` restricts candidates to
    /// the critical band. `None` when nothing is eligible.
    fn select_pos(&self, dest: GpuId, by_class: bool, critical_only: bool) -> Option<usize> {
        let q = &self.pending[dest.0 as usize];
        if !by_class {
            return if q.is_empty() { None } else { Some(0) };
        }
        let mut best: Option<(usize, TransferClass)> = None;
        for (i, c) in q.iter().enumerate() {
            if critical_only && c.class.is_bulk_band() {
                continue;
            }
            match best {
                Some((_, bc)) if bc <= c.class => {}
                _ => best = Some((i, c.class)),
            }
            if c.class == TransferClass::LatencyCritical {
                break; // nothing outranks it
            }
        }
        best.map(|(i, _)| i)
    }

    fn book_push(&mut self, c: &Chunk) {
        let d = c.dest.0 as usize;
        self.remaining[d] += c.bytes;
        if !c.class.is_bulk_band() {
            self.remaining_crit[d] += c.bytes;
        }
        self.class_chunks[c.class as usize] += 1;
        self.total_pending += 1;
    }

    fn book_pop(&mut self, c: &Chunk) {
        let d = c.dest.0 as usize;
        self.remaining[d] -= c.bytes;
        if !c.class.is_bulk_band() {
            self.remaining_crit[d] -= c.bytes;
        }
        self.class_chunks[c.class as usize] -= 1;
        self.total_pending -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn tid(i: u32) -> TransferId {
        TransferId(i)
    }

    fn split(t: u32, dest: GpuId, bytes: u64, chunk: u64) -> Vec<Chunk> {
        TaskManager::split(tid(t), dest, bytes, chunk, TransferClass::Interactive)
    }

    fn split_class(
        t: u32,
        dest: GpuId,
        bytes: u64,
        chunk: u64,
        class: TransferClass,
    ) -> Vec<Chunk> {
        TaskManager::split(tid(t), dest, bytes, chunk, class)
    }

    const LEGACY: PullClassPolicy = PullClassPolicy {
        by_class: false,
        critical_only: false,
        no_bulk_steal: false,
    };

    const QOS: PullClassPolicy = PullClassPolicy {
        by_class: true,
        critical_only: false,
        no_bulk_steal: false,
    };

    fn steal_longest(tm: &mut TaskManager, gpu: GpuId, cp: PullClassPolicy) -> Option<Chunk> {
        tm.pop_steal_scored(gpu, cp, |_, rem| Some(rem as f64))
    }

    #[test]
    fn split_covers_all_bytes_exactly() {
        let chunks = split(1, GpuId(0), 12_000_000, 5_000_000);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].bytes, 5_000_000);
        assert_eq!(chunks[1].bytes, 5_000_000);
        assert_eq!(chunks[2].bytes, 2_000_000);
        assert_eq!(chunks.iter().map(|c| c.bytes).sum::<u64>(), 12_000_000);
        for (i, c) in chunks.iter().enumerate() {
            assert_eq!(c.index, i as u32);
            assert_eq!(c.class, TransferClass::Interactive);
        }
    }

    #[test]
    fn split_property_total_and_sizes() {
        testkit::check("split-total", |rng| {
            let bytes = rng.range_u64(1, 1 << 34);
            let chunk = rng.range_u64(1, 64 << 20);
            let chunks = split(0, GpuId(1), bytes, chunk);
            assert_eq!(chunks.iter().map(|c| c.bytes).sum::<u64>(), bytes);
            for c in &chunks[..chunks.len() - 1] {
                assert_eq!(c.bytes, chunk);
            }
            let tail = chunks.last().unwrap();
            assert!(tail.bytes > 0 && tail.bytes <= chunk);
        });
    }

    #[test]
    fn direct_pop_fifo_per_destination() {
        let mut tm = TaskManager::new(4);
        let a = split(1, GpuId(2), 10, 4);
        tm.push_pending(&a);
        assert!(tm.has_direct(GpuId(2)));
        assert!(!tm.has_direct(GpuId(0)));
        assert_eq!(tm.pop_direct(GpuId(2), LEGACY).unwrap().index, 0);
        assert_eq!(tm.pop_direct(GpuId(2), LEGACY).unwrap().index, 1);
        assert_eq!(tm.pop_direct(GpuId(2), LEGACY).unwrap().index, 2);
        assert!(tm.pop_direct(GpuId(2), LEGACY).is_none());
        assert!(tm.is_empty());
    }

    #[test]
    fn class_priority_pop_reorders_only_under_qos() {
        let mut tm = TaskManager::new(2);
        tm.push_pending(&split_class(1, GpuId(0), 8, 4, TransferClass::Bulk));
        tm.push_pending(&split_class(2, GpuId(0), 4, 4, TransferClass::LatencyCritical));
        // Legacy FIFO: the earlier bulk chunk pops first.
        assert_eq!(tm.pop_direct(GpuId(0), LEGACY).unwrap().class, TransferClass::Bulk);
        // QoS: the critical chunk leapfrogs the remaining bulk one.
        let c = tm.pop_direct(GpuId(0), QOS).unwrap();
        assert_eq!(c.class, TransferClass::LatencyCritical);
        assert_eq!(tm.pop_direct(GpuId(0), QOS).unwrap().class, TransferClass::Bulk);
        assert!(tm.is_empty());
    }

    #[test]
    fn critical_only_round_skips_bulk_band() {
        let mut tm = TaskManager::new(2);
        tm.push_pending(&split_class(1, GpuId(0), 4, 4, TransferClass::Background));
        let throttled = PullClassPolicy {
            critical_only: true,
            ..QOS
        };
        assert!(tm.pop_direct(GpuId(0), throttled).is_none(), "bulk band throttled");
        tm.push_pending(&split_class(2, GpuId(0), 4, 4, TransferClass::Interactive));
        let c = tm.pop_direct(GpuId(0), throttled).unwrap();
        assert_eq!(c.class, TransferClass::Interactive);
        // The background chunk is still there for an unthrottled round.
        assert_eq!(tm.pop_direct(GpuId(0), QOS).unwrap().class, TransferClass::Background);
    }

    #[test]
    fn steal_prefers_longest_remaining_destination() {
        let mut tm = TaskManager::new(4);
        tm.push_pending(&split(1, GpuId(1), 10_000_000, 5_000_000));
        tm.push_pending(&split(2, GpuId(2), 30_000_000, 5_000_000));
        // GPU 0 steals: destination 2 has more remaining.
        let c = steal_longest(&mut tm, GpuId(0), LEGACY).unwrap();
        assert_eq!(c.dest, GpuId(2));
        assert_eq!(tm.remaining_for(GpuId(2)), 25_000_000);
    }

    #[test]
    fn steal_never_takes_own_destination_or_ineligible() {
        let mut tm = TaskManager::new(4);
        tm.push_pending(&split(1, GpuId(0), 50_000_000, 5_000_000));
        tm.push_pending(&split(2, GpuId(3), 10_000_000, 5_000_000));
        // GPU 0's own work is not "relay" work.
        let c = steal_longest(&mut tm, GpuId(0), LEGACY).unwrap();
        assert_eq!(c.dest, GpuId(3));
        // With destination 3 filtered out, nothing remains stealable.
        let none = tm.pop_steal_scored(GpuId(0), LEGACY, |d, rem| {
            (d != GpuId(3)).then_some(rem as f64)
        });
        assert!(none.is_none());
    }

    #[test]
    fn scored_steal_ranks_and_filters() {
        let mut tm = TaskManager::new(4);
        tm.push_pending(&split(1, GpuId(1), 10_000_000, 5_000_000));
        tm.push_pending(&split(2, GpuId(2), 30_000_000, 5_000_000));
        // Inverted scoring: the *smaller* backlog wins.
        let c = tm
            .pop_steal_scored(GpuId(0), LEGACY, |_, rem| Some(1.0 / rem as f64))
            .unwrap();
        assert_eq!(c.dest, GpuId(1));
        // None scores exclude destinations entirely.
        let c = tm
            .pop_steal_scored(GpuId(0), LEGACY, |d, rem| {
                (d != GpuId(2)).then_some(rem as f64)
            })
            .unwrap();
        assert_eq!(c.dest, GpuId(1));
        // Zero scores never win (nothing stealable).
        assert!(tm.pop_steal_scored(GpuId(0), LEGACY, |_, _| Some(0.0)).is_none());
    }

    #[test]
    fn steal_guard_blocks_bulk_onto_critical_paths() {
        let mut tm = TaskManager::new(4);
        tm.push_pending(&split_class(1, GpuId(2), 20_000_000, 5_000_000, TransferClass::Bulk));
        // A path with an in-flight critical chunk refuses bulk steals...
        let guarded = PullClassPolicy {
            no_bulk_steal: true,
            ..QOS
        };
        assert!(steal_longest(&mut tm, GpuId(0), guarded).is_none());
        // ...but critical-band work may still be stolen onto it.
        tm.push_pending(&split_class(
            2,
            GpuId(3),
            5_000_000,
            5_000_000,
            TransferClass::LatencyCritical,
        ));
        let c = steal_longest(&mut tm, GpuId(0), guarded).unwrap();
        assert_eq!(c.dest, GpuId(3));
        assert_eq!(c.class, TransferClass::LatencyCritical);
        // Without the guard (and without QoS at all) bulk steals freely.
        let c = steal_longest(&mut tm, GpuId(0), LEGACY).unwrap();
        assert_eq!(c.class, TransferClass::Bulk);
    }

    #[test]
    fn pending_critical_direct_work_also_blocks_bulk_steals() {
        // The guard's second trigger: the stealing GPU itself has queued
        // critical direct chunks — taking bulk relay work would delay them.
        let mut tm = TaskManager::new(4);
        tm.push_pending(&split_class(
            1,
            GpuId(0),
            5_000_000,
            5_000_000,
            TransferClass::LatencyCritical,
        ));
        tm.push_pending(&split_class(2, GpuId(2), 50_000_000, 5_000_000, TransferClass::Bulk));
        assert!(tm.has_critical_direct(GpuId(0)));
        assert!(
            steal_longest(&mut tm, GpuId(0), QOS).is_none(),
            "bulk steal must wait for the critical direct backlog"
        );
        // Another GPU with no critical work steals the bulk chunk fine.
        assert!(steal_longest(&mut tm, GpuId(1), QOS).is_some());
    }

    #[test]
    fn class_mix_surface_counts_pending_chunks() {
        let mut tm = TaskManager::new(4);
        tm.push_pending(&split_class(1, GpuId(0), 10, 4, TransferClass::LatencyCritical));
        tm.push_pending(&split_class(2, GpuId(1), 4, 4, TransferClass::Bulk));
        let mix = tm.pending_by_class();
        assert_eq!(mix[TransferClass::LatencyCritical as usize], 3);
        assert_eq!(mix[TransferClass::Bulk as usize], 1);
        assert_eq!(tm.critical_pending(), 3);
        tm.pop_direct(GpuId(0), QOS).unwrap();
        assert_eq!(tm.critical_pending(), 2);
    }

    #[test]
    fn assigned_queue_is_per_path_gpu() {
        let mut tm = TaskManager::new(2);
        let chunks = split(1, GpuId(0), 9, 3);
        tm.push_assigned(GpuId(0), chunks[0]);
        tm.push_assigned(GpuId(1), chunks[1]);
        tm.push_assigned(GpuId(1), chunks[2]);
        assert!(tm.has_assigned(GpuId(1)));
        assert_eq!(tm.pop_assigned(GpuId(1)).unwrap().index, 1);
        assert_eq!(tm.pop_assigned(GpuId(0)).unwrap().index, 0);
        assert_eq!(tm.pop_assigned(GpuId(1)).unwrap().index, 2);
        assert!(tm.is_empty());
    }

    #[test]
    fn remaining_bytes_tracks_pop_order() {
        testkit::check("remaining-invariant", |rng| {
            let mut tm = TaskManager::new(4);
            let mut expect = [0u64; 4];
            let mut expect_crit = [0u64; 4];
            for t in 0..rng.range_u64(1, 6) {
                let dest = GpuId(rng.range_u64(0, 4) as u8);
                let bytes = rng.range_u64(1, 40_000_000);
                let class = TransferClass::from_id(rng.range_u64(0, 4) as u8);
                tm.push_pending(&split_class(t as u32, dest, bytes, 5_000_000, class));
                expect[dest.0 as usize] += bytes;
                if !class.is_bulk_band() {
                    expect_crit[dest.0 as usize] += bytes;
                }
            }
            // Drain randomly via direct and steal pops, legacy and QoS.
            loop {
                let g = GpuId(rng.range_u64(0, 4) as u8);
                let cp = if rng.bool(0.5) { LEGACY } else { QOS };
                let c = if rng.bool(0.5) {
                    tm.pop_direct(g, cp)
                } else {
                    steal_longest(&mut tm, g, cp)
                };
                match c {
                    Some(c) => {
                        expect[c.dest.0 as usize] -= c.bytes;
                        if !c.class.is_bulk_band() {
                            expect_crit[c.dest.0 as usize] -= c.bytes;
                        }
                    }
                    None => {
                        if tm.is_empty() {
                            break;
                        }
                    }
                }
                for d in 0..4 {
                    assert_eq!(tm.remaining_for(GpuId(d as u8)), expect[d]);
                    assert_eq!(tm.has_critical_direct(GpuId(d as u8)), expect_crit[d] > 0);
                }
            }
            assert_eq!(expect, [0, 0, 0, 0]);
            assert_eq!(expect_crit, [0, 0, 0, 0]);
        });
    }
}
