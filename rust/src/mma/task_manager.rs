//! Task Manager (§3.4.1): splits each transfer into fixed-size micro-tasks
//! and maintains the destination-tagged micro-task queue of Figure 5.

use crate::gpusim::TransferId;
use crate::topology::GpuId;
use std::collections::VecDeque;

/// One micro-task: a fixed-size slice of a transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Chunk {
    /// Parent transfer.
    pub transfer: TransferId,
    /// Index within the transfer (0-based).
    pub index: u32,
    /// Size of this chunk (the tail chunk may be short).
    pub bytes: u64,
    /// Destination (H2D) or source (D2H) GPU — the "color" in Figure 5.
    pub dest: GpuId,
}

/// Destination-tagged micro-task queue. Chunks of the same destination keep
/// FIFO order; `remaining_bytes` per destination drives the
/// longest-remaining-destination relay-stealing policy (§3.4.2).
pub struct TaskManager {
    pending: Vec<VecDeque<Chunk>>,
    remaining: Vec<u64>,
    /// Statically pre-assigned chunks per path GPU (static-split baseline).
    assigned: Vec<VecDeque<Chunk>>,
    total_pending: usize,
}

impl TaskManager {
    /// Create for a server with `gpu_count` GPUs.
    pub fn new(gpu_count: usize) -> TaskManager {
        TaskManager {
            pending: (0..gpu_count).map(|_| VecDeque::new()).collect(),
            remaining: vec![0; gpu_count],
            assigned: (0..gpu_count).map(|_| VecDeque::new()).collect(),
            total_pending: 0,
        }
    }

    /// Split `bytes` into `chunk_bytes`-sized micro-tasks. The tail chunk
    /// carries the remainder (never zero-sized).
    pub fn split(
        transfer: TransferId,
        dest: GpuId,
        bytes: u64,
        chunk_bytes: u64,
    ) -> Vec<Chunk> {
        assert!(bytes > 0, "empty transfer");
        let cb = chunk_bytes.max(1);
        let n = bytes.div_ceil(cb);
        (0..n)
            .map(|i| {
                let off = i * cb;
                Chunk {
                    transfer,
                    index: i as u32,
                    bytes: (bytes - off).min(cb),
                    dest,
                }
            })
            .collect()
    }

    /// Enqueue chunks into the destination-tagged queue (pull mode).
    pub fn push_pending(&mut self, chunks: &[Chunk]) {
        for c in chunks {
            self.pending[c.dest.0 as usize].push_back(*c);
            self.remaining[c.dest.0 as usize] += c.bytes;
            self.total_pending += 1;
        }
    }

    /// Enqueue a chunk onto a specific path GPU's assigned queue
    /// (static-split mode; no stealing ever happens from these).
    pub fn push_assigned(&mut self, path_gpu: GpuId, chunk: Chunk) {
        self.assigned[path_gpu.0 as usize].push_back(chunk);
        self.total_pending += 1;
    }

    /// Pop the next direct micro-task for `gpu` (dest == gpu).
    pub fn pop_direct(&mut self, gpu: GpuId) -> Option<Chunk> {
        let c = self.pending[gpu.0 as usize].pop_front()?;
        self.remaining[gpu.0 as usize] -= c.bytes;
        self.total_pending -= 1;
        Some(c)
    }

    /// Pop the next statically-assigned micro-task for path `gpu`.
    pub fn pop_assigned(&mut self, gpu: GpuId) -> Option<Chunk> {
        let c = self.assigned[gpu.0 as usize].pop_front()?;
        self.total_pending -= 1;
        Some(c)
    }

    /// Pop a relay micro-task for `gpu`: steals from the destination with
    /// the most remaining pending bytes (§3.4.2, longest-remaining policy).
    /// `eligible` filters candidate destinations (NUMA restrictions etc.).
    pub fn pop_steal(
        &mut self,
        gpu: GpuId,
        mut eligible: impl FnMut(GpuId) -> bool,
    ) -> Option<Chunk> {
        self.pop_steal_scored(gpu, |dest, remaining| {
            if eligible(dest) {
                Some(remaining as f64)
            } else {
                None
            }
        })
    }

    /// Pop a relay micro-task for `gpu` from the destination with the
    /// highest `score(dest, remaining_bytes)`; `None` scores mark a
    /// destination ineligible, ties keep the lowest GPU index. This is the
    /// generalized steal that [`crate::policy`] implementations rank with
    /// (NUMA discounts, backlog thresholds, ...).
    pub fn pop_steal_scored(
        &mut self,
        gpu: GpuId,
        mut score: impl FnMut(GpuId, u64) -> Option<f64>,
    ) -> Option<Chunk> {
        let mut best: Option<GpuId> = None;
        let mut best_score = 0.0f64;
        for d in 0..self.pending.len() {
            let dest = GpuId(d as u8);
            if dest == gpu || self.remaining[d] == 0 {
                continue;
            }
            let Some(s) = score(dest, self.remaining[d]) else {
                continue;
            };
            if s > best_score {
                best_score = s;
                best = Some(dest);
            }
        }
        let dest = best?;
        let c = self.pending[dest.0 as usize].pop_front()?;
        self.remaining[dest.0 as usize] -= c.bytes;
        self.total_pending -= 1;
        Some(c)
    }

    /// Remaining pending bytes for a destination.
    pub fn remaining_for(&self, dest: GpuId) -> u64 {
        self.remaining[dest.0 as usize]
    }

    /// Pending direct work available for `gpu`?
    pub fn has_direct(&self, gpu: GpuId) -> bool {
        !self.pending[gpu.0 as usize].is_empty()
    }

    /// Any statically-assigned work for `gpu`?
    pub fn has_assigned(&self, gpu: GpuId) -> bool {
        !self.assigned[gpu.0 as usize].is_empty()
    }

    /// Total micro-tasks awaiting dispatch.
    pub fn pending_count(&self) -> usize {
        self.total_pending
    }

    /// True when no work is queued anywhere.
    pub fn is_empty(&self) -> bool {
        self.total_pending == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn tid(i: u32) -> TransferId {
        TransferId(i)
    }

    #[test]
    fn split_covers_all_bytes_exactly() {
        let chunks = TaskManager::split(tid(1), GpuId(0), 12_000_000, 5_000_000);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].bytes, 5_000_000);
        assert_eq!(chunks[1].bytes, 5_000_000);
        assert_eq!(chunks[2].bytes, 2_000_000);
        assert_eq!(chunks.iter().map(|c| c.bytes).sum::<u64>(), 12_000_000);
        for (i, c) in chunks.iter().enumerate() {
            assert_eq!(c.index, i as u32);
        }
    }

    #[test]
    fn split_property_total_and_sizes() {
        testkit::check("split-total", |rng| {
            let bytes = rng.range_u64(1, 1 << 34);
            let chunk = rng.range_u64(1, 64 << 20);
            let chunks = TaskManager::split(tid(0), GpuId(1), bytes, chunk);
            assert_eq!(chunks.iter().map(|c| c.bytes).sum::<u64>(), bytes);
            for c in &chunks[..chunks.len() - 1] {
                assert_eq!(c.bytes, chunk);
            }
            let tail = chunks.last().unwrap();
            assert!(tail.bytes > 0 && tail.bytes <= chunk);
        });
    }

    #[test]
    fn direct_pop_fifo_per_destination() {
        let mut tm = TaskManager::new(4);
        let a = TaskManager::split(tid(1), GpuId(2), 10, 4);
        tm.push_pending(&a);
        assert!(tm.has_direct(GpuId(2)));
        assert!(!tm.has_direct(GpuId(0)));
        assert_eq!(tm.pop_direct(GpuId(2)).unwrap().index, 0);
        assert_eq!(tm.pop_direct(GpuId(2)).unwrap().index, 1);
        assert_eq!(tm.pop_direct(GpuId(2)).unwrap().index, 2);
        assert!(tm.pop_direct(GpuId(2)).is_none());
        assert!(tm.is_empty());
    }

    #[test]
    fn steal_prefers_longest_remaining_destination() {
        let mut tm = TaskManager::new(4);
        tm.push_pending(&TaskManager::split(tid(1), GpuId(1), 10_000_000, 5_000_000));
        tm.push_pending(&TaskManager::split(tid(2), GpuId(2), 30_000_000, 5_000_000));
        // GPU 0 steals: destination 2 has more remaining.
        let c = tm.pop_steal(GpuId(0), |_| true).unwrap();
        assert_eq!(c.dest, GpuId(2));
        assert_eq!(tm.remaining_for(GpuId(2)), 25_000_000);
    }

    #[test]
    fn steal_never_takes_own_destination_or_ineligible() {
        let mut tm = TaskManager::new(4);
        tm.push_pending(&TaskManager::split(tid(1), GpuId(0), 50_000_000, 5_000_000));
        tm.push_pending(&TaskManager::split(tid(2), GpuId(3), 10_000_000, 5_000_000));
        // GPU 0's own work is not "relay" work.
        let c = tm.pop_steal(GpuId(0), |_| true).unwrap();
        assert_eq!(c.dest, GpuId(3));
        // With destination 3 filtered out, nothing remains stealable.
        assert!(tm.pop_steal(GpuId(0), |d| d != GpuId(3)).is_none());
    }

    #[test]
    fn scored_steal_ranks_and_filters() {
        let mut tm = TaskManager::new(4);
        tm.push_pending(&TaskManager::split(tid(1), GpuId(1), 10_000_000, 5_000_000));
        tm.push_pending(&TaskManager::split(tid(2), GpuId(2), 30_000_000, 5_000_000));
        // Inverted scoring: the *smaller* backlog wins.
        let c = tm
            .pop_steal_scored(GpuId(0), |_, rem| Some(1.0 / rem as f64))
            .unwrap();
        assert_eq!(c.dest, GpuId(1));
        // None scores exclude destinations entirely.
        let c = tm
            .pop_steal_scored(GpuId(0), |d, rem| {
                (d != GpuId(2)).then_some(rem as f64)
            })
            .unwrap();
        assert_eq!(c.dest, GpuId(1));
        // Zero scores never win (nothing stealable).
        assert!(tm.pop_steal_scored(GpuId(0), |_, _| Some(0.0)).is_none());
    }

    #[test]
    fn assigned_queue_is_per_path_gpu() {
        let mut tm = TaskManager::new(2);
        let chunks = TaskManager::split(tid(1), GpuId(0), 9, 3);
        tm.push_assigned(GpuId(0), chunks[0]);
        tm.push_assigned(GpuId(1), chunks[1]);
        tm.push_assigned(GpuId(1), chunks[2]);
        assert!(tm.has_assigned(GpuId(1)));
        assert_eq!(tm.pop_assigned(GpuId(1)).unwrap().index, 1);
        assert_eq!(tm.pop_assigned(GpuId(0)).unwrap().index, 0);
        assert_eq!(tm.pop_assigned(GpuId(1)).unwrap().index, 2);
        assert!(tm.is_empty());
    }

    #[test]
    fn remaining_bytes_tracks_pop_order() {
        testkit::check("remaining-invariant", |rng| {
            let mut tm = TaskManager::new(4);
            let mut expect = [0u64; 4];
            for t in 0..rng.range_u64(1, 6) {
                let dest = GpuId(rng.range_u64(0, 4) as u8);
                let bytes = rng.range_u64(1, 40_000_000);
                tm.push_pending(&TaskManager::split(tid(t as u32), dest, bytes, 5_000_000));
                expect[dest.0 as usize] += bytes;
            }
            // Drain randomly via direct and steal pops.
            loop {
                let g = GpuId(rng.range_u64(0, 4) as u8);
                let c = if rng.bool(0.5) {
                    tm.pop_direct(g)
                } else {
                    tm.pop_steal(g, |_| true)
                };
                match c {
                    Some(c) => expect[c.dest.0 as usize] -= c.bytes,
                    None => {
                        if tm.is_empty() {
                            break;
                        }
                    }
                }
                for d in 0..4 {
                    assert_eq!(tm.remaining_for(GpuId(d as u8)), expect[d]);
                }
            }
            assert_eq!(expect, [0, 0, 0, 0]);
        });
    }
}
