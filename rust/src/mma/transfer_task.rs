//! Transfer Tasks: the recorded payload of an intercepted host↔GPU copy.

use crate::gpusim::{FlagId, StreamId, TransferId};
use crate::sim::Time;
use crate::topology::{Direction, GpuId, NumaId};

/// Number of QoS traffic classes (the [`TransferClass`] variants).
pub const NUM_CLASSES: usize = 4;

/// First-class QoS traffic class carried by every transfer, end to end:
/// the fabric turns it into a weighted max-min share weight (plus an
/// optional bulk rate cap), the engine into class-aware issue ordering and
/// bulk depth throttling, and the serving layer tags its traffic with it.
/// The discriminant doubles as the class's priority (lower = more urgent)
/// and as its per-class bandwidth-sampling channel (Fig 9 time series).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[repr(u8)]
pub enum TransferClass {
    /// TTFT-critical traffic: prefix/KV fetches feeding a waiting request.
    LatencyCritical = 0,
    /// Request/decode-path traffic that users notice but that is not on
    /// the first-token critical path. The default for untagged copies.
    Interactive = 1,
    /// Large throughput-bound movement: model sleep/wake weight reloads,
    /// bulk KV offload sweeps.
    Bulk = 2,
    /// Best-effort background churn (prefetchers, co-running native apps).
    Background = 3,
}

impl TransferClass {
    /// Every class, in priority order (most urgent first).
    pub const ALL: [TransferClass; NUM_CLASSES] = [
        TransferClass::LatencyCritical,
        TransferClass::Interactive,
        TransferClass::Bulk,
        TransferClass::Background,
    ];

    /// Stable wire id (flow-tag byte / sampling channel).
    pub fn id(self) -> u8 {
        self as u8
    }

    /// Inverse of [`Self::id`]; out-of-range ids clamp to `Background`.
    pub fn from_id(id: u8) -> TransferClass {
        match id {
            0 => TransferClass::LatencyCritical,
            1 => TransferClass::Interactive,
            2 => TransferClass::Bulk,
            _ => TransferClass::Background,
        }
    }

    /// Is this one of the throughput-bound classes the QoS layer throttles
    /// in favor of latency-critical traffic?
    pub fn is_bulk_band(self) -> bool {
        matches!(self, TransferClass::Bulk | TransferClass::Background)
    }

    /// Canonical lowercase name (config/CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            TransferClass::LatencyCritical => "latency-critical",
            TransferClass::Interactive => "interactive",
            TransferClass::Bulk => "bulk",
            TransferClass::Background => "background",
        }
    }

    /// Inverse of [`Self::name`] (the trace / config / CLI spelling).
    /// `None` for unknown names — callers decide whether that's an error.
    pub fn parse(s: &str) -> Option<TransferClass> {
        match s.to_ascii_lowercase().as_str() {
            "latency-critical" | "critical" => Some(TransferClass::LatencyCritical),
            "interactive" => Some(TransferClass::Interactive),
            "bulk" => Some(TransferClass::Bulk),
            "background" => Some(TransferClass::Background),
            _ => None,
        }
    }
}

/// Description of one logical copy as submitted by the app: host↔GPU, or
/// (when [`Self::peer`] is set) GPU→GPU over the NVLink fabric.
#[derive(Clone, Copy, Debug)]
pub struct TransferDesc {
    /// Copy direction (for peer copies, always H2D "into `gpu`").
    pub dir: Direction,
    /// The target (H2D) or source (D2H) GPU.
    pub gpu: GpuId,
    /// NUMA node holding the pinned host buffer (unused for peer copies).
    pub host_numa: NumaId,
    /// Payload size in bytes.
    pub bytes: u64,
    /// QoS traffic class (weighted fabric share + engine issue priority).
    pub class: TransferClass,
    /// Peer source GPU for a GPU→GPU copy (`cudaMemcpyPeerAsync`). Peer
    /// copies ride the NVSwitch fabric as one native P2P DMA and are never
    /// intercepted by the engine (§3.2: GPU↔GPU traffic has its own path).
    pub peer: Option<GpuId>,
}

impl TransferDesc {
    /// Convenience constructor for `Interactive`-class host↔GPU traffic
    /// (the default for untagged copies).
    pub fn new(dir: Direction, gpu: GpuId, host_numa: NumaId, bytes: u64) -> TransferDesc {
        TransferDesc {
            dir,
            gpu,
            host_numa,
            bytes,
            class: TransferClass::Interactive,
            peer: None,
        }
    }

    /// GPU→GPU peer copy: `src`'s HBM → `dst`'s HBM over the NVLink
    /// fabric (`Interactive` class). `host_numa` is irrelevant for the
    /// peer path.
    pub fn p2p(src: GpuId, dst: GpuId, bytes: u64) -> TransferDesc {
        TransferDesc {
            dir: Direction::H2D,
            gpu: dst,
            host_numa: NumaId(0),
            bytes,
            class: TransferClass::Interactive,
            peer: Some(src),
        }
    }

    /// Same descriptor re-tagged with a QoS class (builder style).
    pub fn with_class(mut self, class: TransferClass) -> TransferDesc {
        self.class = class;
        self
    }
}

/// How the copy was submitted (decides completion semantics).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SubmitKind {
    /// `cudaMemcpyAsync` on a stream: completion is stream-visible via the
    /// Dummy Task.
    Async {
        /// Stream the Dummy Task occupies.
        stream: StreamId,
    },
    /// `cudaMemcpy`: the calling thread blocks until completion.
    Sync,
}

/// Lifecycle of an intercepted transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TransferState {
    /// Recorded; the Dummy Task has not reached its copy point yet.
    Recorded,
    /// Copy point active: the Multipath Transfer Engine is moving chunks.
    Active,
    /// All micro-tasks delivered; flag set / caller woken.
    Complete,
}

/// Full bookkeeping record for one transfer (driver-owned).
#[derive(Clone, Debug)]
pub struct TransferRec {
    /// Stable id (index).
    pub id: TransferId,
    /// What was asked.
    pub desc: TransferDesc,
    /// How it was submitted.
    pub kind: SubmitKind,
    /// Engine ("process") that owns it; `None` for native-path copies.
    pub engine: Option<u8>,
    /// Mapped flag of the Dummy Task, for async intercepted copies.
    pub flag: Option<FlagId>,
    /// State machine.
    pub state: TransferState,
    /// Submission time (API call).
    pub submitted: Time,
    /// When the copy point became active (stream reached the Dummy Task or
    /// the engine started a sync copy / native DMA).
    pub activated: Option<Time>,
    /// When the payload finished landing (all chunks delivered / native
    /// flow completed). For async copies the spin kernel releases one PCIe
    /// RTT later.
    pub completed: Option<Time>,
    /// When downstream stream work was released (async only).
    pub released: Option<Time>,
    /// Bytes that travelled the direct path.
    pub bytes_direct: u64,
    /// Bytes that travelled relay paths.
    pub bytes_relay: u64,
}

impl TransferRec {
    /// Effective bandwidth over the *host-visible* transfer interval
    /// (submission → payload complete), bytes/sec.
    pub fn bandwidth(&self) -> Option<f64> {
        let done = self.completed?;
        let dt = done.since(self.submitted).as_secs_f64();
        if dt <= 0.0 {
            return None;
        }
        Some(self.desc.bytes as f64 / dt)
    }

    /// Effective bandwidth counted from activation (excludes stream queue
    /// wait), bytes/sec.
    pub fn active_bandwidth(&self) -> Option<f64> {
        let done = self.completed?;
        let t0 = self.activated?;
        let dt = done.since(t0).as_secs_f64();
        if dt <= 0.0 {
            return None;
        }
        Some(self.desc.bytes as f64 / dt)
    }

    /// Fraction of bytes that went over the direct path.
    pub fn direct_fraction(&self) -> f64 {
        let total = self.bytes_direct + self.bytes_relay;
        if total == 0 {
            return 0.0;
        }
        self.bytes_direct as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(bytes: u64) -> TransferRec {
        TransferRec {
            id: TransferId(0),
            desc: TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), bytes),
            kind: SubmitKind::Sync,
            engine: Some(0),
            flag: None,
            state: TransferState::Recorded,
            submitted: Time::from_us(10),
            activated: None,
            completed: None,
            released: None,
            bytes_direct: 0,
            bytes_relay: 0,
        }
    }

    #[test]
    fn bandwidth_requires_completion() {
        let mut r = rec(1_000_000_000);
        assert!(r.bandwidth().is_none());
        r.completed = Some(Time::from_us(10) + Time::from_ms(20));
        let bw = r.bandwidth().unwrap();
        assert!((bw - 50e9).abs() < 1e6, "{bw}");
    }

    #[test]
    fn active_bandwidth_excludes_queue_wait() {
        let mut r = rec(1_000_000_000);
        r.activated = Some(Time::from_ms(5));
        r.completed = Some(Time::from_ms(25));
        let bw = r.active_bandwidth().unwrap();
        assert!((bw - 50e9).abs() < 1e6);
        // Host-visible bandwidth is lower because of the 5 ms queue wait.
        assert!(r.bandwidth().unwrap() < bw);
    }

    #[test]
    fn class_ids_roundtrip_and_order_by_urgency() {
        for c in TransferClass::ALL {
            assert_eq!(TransferClass::from_id(c.id()), c);
        }
        assert_eq!(TransferClass::from_id(200), TransferClass::Background);
        // Priority order: lower id = more urgent (Ord matches).
        assert!(TransferClass::LatencyCritical < TransferClass::Interactive);
        assert!(TransferClass::Interactive < TransferClass::Bulk);
        assert!(TransferClass::Bulk < TransferClass::Background);
        assert!(!TransferClass::Interactive.is_bulk_band());
        assert!(TransferClass::Background.is_bulk_band());
    }

    #[test]
    fn class_names_roundtrip_through_parse() {
        for c in TransferClass::ALL {
            assert_eq!(TransferClass::parse(c.name()), Some(c));
        }
        assert_eq!(
            TransferClass::parse("CRITICAL"),
            Some(TransferClass::LatencyCritical)
        );
        assert_eq!(TransferClass::parse("nope"), None);
    }

    #[test]
    fn descriptors_default_interactive_and_retag() {
        let d = TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), 10);
        assert_eq!(d.class, TransferClass::Interactive);
        let d = d.with_class(TransferClass::LatencyCritical);
        assert_eq!(d.class, TransferClass::LatencyCritical);
        let p = TransferDesc::p2p(GpuId(0), GpuId(1), 10).with_class(TransferClass::Bulk);
        assert_eq!(p.class, TransferClass::Bulk);
        assert_eq!(p.peer, Some(GpuId(0)));
    }

    #[test]
    fn direct_fraction() {
        let mut r = rec(100);
        r.bytes_direct = 30;
        r.bytes_relay = 70;
        assert!((r.direct_fraction() - 0.3).abs() < 1e-12);
        let r2 = rec(100);
        assert_eq!(r2.direct_fraction(), 0.0);
    }
}
