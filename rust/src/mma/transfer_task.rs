//! Transfer Tasks: the recorded payload of an intercepted host↔GPU copy.

use crate::gpusim::{FlagId, StreamId, TransferId};
use crate::sim::Time;
use crate::topology::{Direction, GpuId, NumaId};

/// Caller-assigned traffic class, used by the figure harnesses to plot
/// per-class bandwidth over time (Fig 9). Class 0 is "background".
pub type TransferClass = u8;

/// Description of one logical copy as submitted by the app: host↔GPU, or
/// (when [`Self::peer`] is set) GPU→GPU over the NVLink fabric.
#[derive(Clone, Copy, Debug)]
pub struct TransferDesc {
    /// Copy direction (for peer copies, always H2D "into `gpu`").
    pub dir: Direction,
    /// The target (H2D) or source (D2H) GPU.
    pub gpu: GpuId,
    /// NUMA node holding the pinned host buffer (unused for peer copies).
    pub host_numa: NumaId,
    /// Payload size in bytes.
    pub bytes: u64,
    /// Traffic class for reporting.
    pub class: TransferClass,
    /// Peer source GPU for a GPU→GPU copy (`cudaMemcpyPeerAsync`). Peer
    /// copies ride the NVSwitch fabric as one native P2P DMA and are never
    /// intercepted by the engine (§3.2: GPU↔GPU traffic has its own path).
    pub peer: Option<GpuId>,
}

impl TransferDesc {
    /// Convenience constructor for class-1 (foreground) host↔GPU traffic.
    pub fn new(dir: Direction, gpu: GpuId, host_numa: NumaId, bytes: u64) -> TransferDesc {
        TransferDesc {
            dir,
            gpu,
            host_numa,
            bytes,
            class: 1,
            peer: None,
        }
    }

    /// GPU→GPU peer copy: `src`'s HBM → `dst`'s HBM over the NVLink
    /// fabric (class 1). `host_numa` is irrelevant for the peer path.
    pub fn p2p(src: GpuId, dst: GpuId, bytes: u64) -> TransferDesc {
        TransferDesc {
            dir: Direction::H2D,
            gpu: dst,
            host_numa: NumaId(0),
            bytes,
            class: 1,
            peer: Some(src),
        }
    }
}

/// How the copy was submitted (decides completion semantics).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SubmitKind {
    /// `cudaMemcpyAsync` on a stream: completion is stream-visible via the
    /// Dummy Task.
    Async {
        /// Stream the Dummy Task occupies.
        stream: StreamId,
    },
    /// `cudaMemcpy`: the calling thread blocks until completion.
    Sync,
}

/// Lifecycle of an intercepted transfer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TransferState {
    /// Recorded; the Dummy Task has not reached its copy point yet.
    Recorded,
    /// Copy point active: the Multipath Transfer Engine is moving chunks.
    Active,
    /// All micro-tasks delivered; flag set / caller woken.
    Complete,
}

/// Full bookkeeping record for one transfer (driver-owned).
#[derive(Clone, Debug)]
pub struct TransferRec {
    /// Stable id (index).
    pub id: TransferId,
    /// What was asked.
    pub desc: TransferDesc,
    /// How it was submitted.
    pub kind: SubmitKind,
    /// Engine ("process") that owns it; `None` for native-path copies.
    pub engine: Option<u8>,
    /// Mapped flag of the Dummy Task, for async intercepted copies.
    pub flag: Option<FlagId>,
    /// State machine.
    pub state: TransferState,
    /// Submission time (API call).
    pub submitted: Time,
    /// When the copy point became active (stream reached the Dummy Task or
    /// the engine started a sync copy / native DMA).
    pub activated: Option<Time>,
    /// When the payload finished landing (all chunks delivered / native
    /// flow completed). For async copies the spin kernel releases one PCIe
    /// RTT later.
    pub completed: Option<Time>,
    /// When downstream stream work was released (async only).
    pub released: Option<Time>,
    /// Bytes that travelled the direct path.
    pub bytes_direct: u64,
    /// Bytes that travelled relay paths.
    pub bytes_relay: u64,
}

impl TransferRec {
    /// Effective bandwidth over the *host-visible* transfer interval
    /// (submission → payload complete), bytes/sec.
    pub fn bandwidth(&self) -> Option<f64> {
        let done = self.completed?;
        let dt = done.since(self.submitted).as_secs_f64();
        if dt <= 0.0 {
            return None;
        }
        Some(self.desc.bytes as f64 / dt)
    }

    /// Effective bandwidth counted from activation (excludes stream queue
    /// wait), bytes/sec.
    pub fn active_bandwidth(&self) -> Option<f64> {
        let done = self.completed?;
        let t0 = self.activated?;
        let dt = done.since(t0).as_secs_f64();
        if dt <= 0.0 {
            return None;
        }
        Some(self.desc.bytes as f64 / dt)
    }

    /// Fraction of bytes that went over the direct path.
    pub fn direct_fraction(&self) -> f64 {
        let total = self.bytes_direct + self.bytes_relay;
        if total == 0 {
            return 0.0;
        }
        self.bytes_direct as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(bytes: u64) -> TransferRec {
        TransferRec {
            id: TransferId(0),
            desc: TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), bytes),
            kind: SubmitKind::Sync,
            engine: Some(0),
            flag: None,
            state: TransferState::Recorded,
            submitted: Time::from_us(10),
            activated: None,
            completed: None,
            released: None,
            bytes_direct: 0,
            bytes_relay: 0,
        }
    }

    #[test]
    fn bandwidth_requires_completion() {
        let mut r = rec(1_000_000_000);
        assert!(r.bandwidth().is_none());
        r.completed = Some(Time::from_us(10) + Time::from_ms(20));
        let bw = r.bandwidth().unwrap();
        assert!((bw - 50e9).abs() < 1e6, "{bw}");
    }

    #[test]
    fn active_bandwidth_excludes_queue_wait() {
        let mut r = rec(1_000_000_000);
        r.activated = Some(Time::from_ms(5));
        r.completed = Some(Time::from_ms(25));
        let bw = r.active_bandwidth().unwrap();
        assert!((bw - 50e9).abs() < 1e6);
        // Host-visible bandwidth is lower because of the 5 ms queue wait.
        assert!(r.bandwidth().unwrap() < bw);
    }

    #[test]
    fn direct_fraction() {
        let mut r = rec(100);
        r.bytes_direct = 30;
        r.bytes_relay = 70;
        assert!((r.direct_fraction() - 0.3).abs() < 1e-12);
        let r2 = rec(100);
        assert_eq!(r2.direct_fraction(), 0.0);
    }
}
