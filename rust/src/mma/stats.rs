//! Per-engine counters and CPU-time accounting.
//!
//! The paper's default flow-control mode spawns, per engine (H2D/D2H),
//! three threads per GPU: *transfer*, *synchronization*, *monitor* (§4).
//! Only sync threads busy-wait (`cudaEventSynchronize` with spin
//! scheduling); transfer threads burn CPU proportional to dispatch count;
//! monitors are negligible. Fig 11 reports the total as equivalent
//! fully-loaded cores — we reproduce that accounting here.

use crate::sim::Time;
use crate::topology::GpuId;

/// Stats for one engine instance (one direction of one "process").
#[derive(Debug, Clone)]
pub struct EngineStats {
    /// Chunks dispatched per GPU path.
    pub chunks_dispatched: Vec<u64>,
    /// Of which relay chunks.
    pub relay_chunks: Vec<u64>,
    /// Bytes moved per GPU path.
    pub bytes_by_path: Vec<u64>,
    /// CPU ns burned by transfer threads (dispatch work), per GPU.
    pub transfer_cpu_ns: Vec<u64>,
    /// CPU ns burned by sync threads (busy-wait while their outstanding
    /// queue is non-empty), per GPU.
    pub sync_cpu_ns: Vec<u64>,
    /// Time each queue last became non-empty (None = currently empty).
    busy_since: Vec<Option<Time>>,
    /// Per-GPU count of contention backoff activations.
    pub backoff_events: Vec<u64>,
    /// Completed transfers.
    pub transfers_completed: u64,
    /// Transfers that took the native fallback path.
    pub fallback_transfers: u64,
    /// Completion/retirement notices for unknown or already-retired chunk
    /// keys — counted and skipped instead of aborting the replay.
    pub stray_events: u64,
}

impl EngineStats {
    /// Zeroed stats for `gpu_count` paths.
    pub fn new(gpu_count: usize) -> EngineStats {
        EngineStats {
            chunks_dispatched: vec![0; gpu_count],
            relay_chunks: vec![0; gpu_count],
            bytes_by_path: vec![0; gpu_count],
            transfer_cpu_ns: vec![0; gpu_count],
            sync_cpu_ns: vec![0; gpu_count],
            busy_since: vec![None; gpu_count],
            backoff_events: vec![0; gpu_count],
            transfers_completed: 0,
            fallback_transfers: 0,
            stray_events: 0,
        }
    }

    /// The outstanding queue for `gpu` became non-empty at `now`.
    pub fn queue_busy(&mut self, gpu: GpuId, now: Time) {
        let slot = &mut self.busy_since[gpu.0 as usize];
        if slot.is_none() {
            *slot = Some(now);
        }
    }

    /// The outstanding queue for `gpu` drained at `now`: account the
    /// busy-wait interval to the sync thread.
    pub fn queue_idle(&mut self, gpu: GpuId, now: Time) {
        if let Some(since) = self.busy_since[gpu.0 as usize].take() {
            self.sync_cpu_ns[gpu.0 as usize] += now.since(since).ns();
        }
    }

    /// Close any open busy intervals (end of run) at `now`.
    pub fn finish(&mut self, now: Time) {
        for g in 0..self.busy_since.len() {
            self.queue_idle(GpuId(g as u8), now);
        }
    }

    /// Record one dispatched chunk.
    pub fn dispatched(&mut self, path_gpu: GpuId, bytes: u64, relay: bool, cpu_ns: u64) {
        let i = path_gpu.0 as usize;
        self.chunks_dispatched[i] += 1;
        if relay {
            self.relay_chunks[i] += 1;
        }
        self.bytes_by_path[i] += bytes;
        self.transfer_cpu_ns[i] += cpu_ns;
    }

    /// Total CPU ns across thread classes (transfer + sync + monitor).
    /// Sync threads spin in `cudaEventSynchronize` at ~50% duty (they block
    /// on a condvar between micro-task batches, §5.3); the monitor thread
    /// is ~2% of a core while its path is active ("negligible", §4).
    pub fn total_cpu_ns(&self) -> u64 {
        let xfer: u64 = self.transfer_cpu_ns.iter().sum();
        let sync: u64 = self.sync_cpu_ns.iter().map(|&b| b / 2).sum();
        let monitor: u64 = self.sync_cpu_ns.iter().map(|&b| b / 50).sum();
        xfer + sync + monitor
    }

    /// Equivalent fully-loaded cores over an elapsed window (Fig 11).
    pub fn equivalent_cores(&self, elapsed: Time) -> f64 {
        if elapsed.ns() == 0 {
            return 0.0;
        }
        self.total_cpu_ns() as f64 / elapsed.ns() as f64
    }

    /// Total relay bytes (all paths).
    pub fn total_relay_chunks(&self) -> u64 {
        self.relay_chunks.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn busy_intervals_accumulate() {
        let mut s = EngineStats::new(2);
        s.queue_busy(GpuId(0), Time::from_us(10));
        // Double-busy is idempotent.
        s.queue_busy(GpuId(0), Time::from_us(12));
        s.queue_idle(GpuId(0), Time::from_us(30));
        assert_eq!(s.sync_cpu_ns[0], 20_000);
        // Idle again is a no-op.
        s.queue_idle(GpuId(0), Time::from_us(40));
        assert_eq!(s.sync_cpu_ns[0], 20_000);
    }

    #[test]
    fn finish_closes_open_intervals() {
        let mut s = EngineStats::new(1);
        s.queue_busy(GpuId(0), Time::from_us(5));
        s.finish(Time::from_us(25));
        assert_eq!(s.sync_cpu_ns[0], 20_000);
    }

    #[test]
    fn equivalent_cores_math() {
        let mut s = EngineStats::new(1);
        s.queue_busy(GpuId(0), Time::ZERO);
        s.queue_idle(GpuId(0), Time::from_ms(1));
        // sync = 1ms at 50% duty, monitor = 2% of that, transfer = 0.
        let cores = s.equivalent_cores(Time::from_ms(1));
        assert!((cores - 0.52).abs() < 1e-9, "{cores}");
    }

    #[test]
    fn dispatch_counters() {
        let mut s = EngineStats::new(3);
        s.dispatched(GpuId(1), 5_000_000, false, 3_000);
        s.dispatched(GpuId(1), 5_000_000, true, 3_000);
        assert_eq!(s.chunks_dispatched[1], 2);
        assert_eq!(s.relay_chunks[1], 1);
        assert_eq!(s.bytes_by_path[1], 10_000_000);
        assert_eq!(s.transfer_cpu_ns[1], 6_000);
        assert_eq!(s.total_relay_chunks(), 1);
    }
}
