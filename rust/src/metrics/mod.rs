//! Metrics: streaming histograms, percentiles, and serving-latency
//! trackers (TTFT, TPOT) shared by the serving layer and the harnesses.

use crate::sim::Time;

pub mod hist;

pub use hist::LogHistogram;

/// A simple exact-sample summary. Memory grows with sample count and
/// percentiles sort — it doubles as the accuracy oracle for the
/// bounded-memory [`LogHistogram`], which hot paths should prefer.
#[derive(Debug, Clone)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
    min: f64,
    max: f64,
}

impl Default for Summary {
    fn default() -> Summary {
        Summary {
            samples: Vec::new(),
            sorted: true,
            // Fold identities of the retired O(n) min/max scans, so the
            // empty-summary results (0.0 for both) are unchanged.
            min: f64::INFINITY,
            max: 0.0,
        }
    }
}

impl Summary {
    /// Empty summary.
    pub fn new() -> Summary {
        Summary::default()
    }

    /// Record a sample; min/max update incrementally here so the getters
    /// stay O(1).
    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Record a duration in seconds.
    pub fn record_time(&mut self, t: Time) {
        self.record(t.as_secs_f64());
    }

    /// Number of samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Minimum (0 if empty), O(1).
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.min
    }

    /// Maximum (0 if empty), O(1). Matches the retired fold, whose
    /// identity was 0.0 (not `-inf`).
    pub fn max(&self) -> f64 {
        self.max
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile in `[0,100]` by nearest-rank (0 if empty).
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let idx = ((self.samples.len() - 1) as f64 * (p / 100.0)).round() as usize;
        self.samples[idx]
    }

    /// p50 shortcut.
    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }
    /// p99 shortcut.
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Per-request serving latency breakdown (drives Fig 2 / Fig 12).
#[derive(Debug, Clone, Copy, Default)]
pub struct TtftBreakdown {
    /// Queueing before scheduling, seconds.
    pub queue_s: f64,
    /// Prefix-cache KV fetch (host→GPU), seconds.
    pub fetch_s: f64,
    /// Prefill compute, seconds.
    pub prefill_s: f64,
}

impl TtftBreakdown {
    /// Total TTFT.
    pub fn total(&self) -> f64 {
        self.queue_s + self.fetch_s + self.prefill_s
    }
    /// Fraction of TTFT spent fetching KV pages (the Fig 2 metric).
    pub fn fetch_fraction(&self) -> f64 {
        let t = self.total();
        if t <= 0.0 {
            0.0
        } else {
            self.fetch_s / t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_stats() {
        let mut s = Summary::new();
        for v in [3.0, 1.0, 2.0, 4.0, 5.0] {
            s.record(v);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.p50(), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn min_max_track_incrementally() {
        let mut s = Summary::new();
        s.record(4.0);
        assert_eq!(s.min(), 4.0);
        assert_eq!(s.max(), 4.0);
        s.record(1.5);
        s.record(9.0);
        assert_eq!(s.min(), 1.5);
        assert_eq!(s.max(), 9.0);
        let _ = s.p50(); // sorting must not disturb the tracked extremes
        assert_eq!(s.min(), 1.5);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn empty_summary_is_zero() {
        let mut s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.p99(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn ttft_breakdown_fraction() {
        let b = TtftBreakdown {
            queue_s: 0.01,
            fetch_s: 0.7,
            prefill_s: 0.29,
        };
        assert!((b.total() - 1.0).abs() < 1e-12);
        assert!((b.fetch_fraction() - 0.7).abs() < 1e-12);
        assert_eq!(TtftBreakdown::default().fetch_fraction(), 0.0);
    }
}
