//! A log-binned streaming histogram: O(1) `record`, O(bins) percentile,
//! fixed memory — the bounded-memory replacement for keeping every
//! latency sample in a [`super::Summary`] vector and re-sorting per
//! percentile query.
//!
//! # Binning and the error bound
//!
//! Bins cover `[LO, HI)` = `[1e-9, 1e3)` seconds (sub-nanosecond to
//! ~17 minutes — every latency this simulator produces) in geometric
//! steps: bin `i` spans `[LO·r^i, LO·r^(i+1))` with
//! `r = (HI/LO)^(1/bins)`. A percentile query walks the counts to the
//! nearest-rank bin (the same rank rule as [`super::Summary`]) and
//! returns the bin's geometric midpoint `LO·r^(i+0.5)`.
//!
//! Binning is monotone — larger samples land in weakly larger bins — so
//! the walk's bin always *contains* the exact nearest-rank sample, and
//! the midpoint is within a factor `sqrt(r)` of it. The relative error
//! of any percentile is therefore bounded by [`LogHistogram::rel_error_bound`]
//! `= sqrt(r) − 1` (≈1.36% at the default 1024 bins over 12 decades);
//! halving the bins doubles the decades per bin and roughly doubles the
//! bound. Out-of-range samples keep the bound honest at the extremes:
//! values below `LO` (including zero) are reported as the exact tracked
//! minimum, values at or above `HI` as the exact tracked maximum.
//! `count`, `sum`/`mean`, `min`, and `max` are always exact.

use crate::sim::Time;

/// Lower edge of the binned range, seconds.
const LO: f64 = 1e-9;
/// Upper edge of the binned range, seconds.
const HI: f64 = 1e3;

/// Default bin count (≈1.36% relative error over 12 decades).
pub const DEFAULT_BINS: usize = 1024;

/// The streaming histogram. Memory is `O(bins)` and never grows.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    bins: Vec<u64>,
    /// Samples below `LO` (including zero/negative): reported as `min`.
    under: u64,
    /// Samples at or above `HI`: reported as `max`.
    over: u64,
    inv_ln_ratio: f64,
    ratio: f64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> LogHistogram {
        LogHistogram::new(DEFAULT_BINS)
    }
}

impl LogHistogram {
    /// Histogram with `bins` geometric buckets over `[1e-9, 1e3)` s.
    pub fn new(bins: usize) -> LogHistogram {
        let bins = bins.max(1);
        let ratio = (HI / LO).powf(1.0 / bins as f64);
        LogHistogram {
            bins: vec![0; bins],
            under: 0,
            over: 0,
            inv_ln_ratio: 1.0 / ratio.ln(),
            ratio,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample, O(1).
    pub fn record(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        if v < LO {
            self.under += 1;
        } else if v >= HI {
            self.over += 1;
        } else {
            let idx = ((v / LO).ln() * self.inv_ln_ratio) as usize;
            let idx = idx.min(self.bins.len() - 1); // float-edge safety
            self.bins[idx] += 1;
        }
    }

    /// Record a duration in seconds.
    pub fn record_time(&mut self, t: Time) {
        self.record(t.as_secs_f64());
    }

    /// Number of samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum / self.count as f64
    }

    /// Exact minimum (0 if empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.min
    }

    /// Exact maximum (0 if empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.max
    }

    /// Percentile in `[0,100]` by nearest-rank (0 if empty), O(bins).
    /// Accurate to [`Self::rel_error_bound`] for in-range samples; exact
    /// at the tracked extremes for out-of-range ones.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        // Same rank rule as `Summary::percentile` over the sorted samples;
        // binning is monotone, so walking counts lands in the bin that
        // contains the exact nearest-rank sample.
        let rank = ((self.count - 1) as f64 * (p / 100.0)).round() as u64;
        let mut seen = self.under;
        if rank < seen {
            return self.min;
        }
        for (i, &n) in self.bins.iter().enumerate() {
            seen += n;
            if rank < seen {
                return LO * self.ratio.powf(i as f64 + 0.5);
            }
        }
        self.max
    }

    /// p50 shortcut.
    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    /// p99 shortcut.
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Worst-case relative error of an in-range percentile:
    /// `sqrt(ratio) − 1` where `ratio` is the per-bin geometric step.
    pub fn rel_error_bound(&self) -> f64 {
        self.ratio.sqrt() - 1.0
    }

    /// Fixed memory footprint of the bin array plus counters, bytes.
    /// Unlike a sample vector this never grows with `record` volume.
    pub fn tracked_bytes(&self) -> u64 {
        (self.bins.len() * std::mem::size_of::<u64>() + std::mem::size_of::<LogHistogram>())
            as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Summary;
    use crate::util::rng::Rng;

    #[test]
    fn empty_histogram_is_zero() {
        let h = LogHistogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
    }

    #[test]
    fn exact_fields_are_exact() {
        let mut h = LogHistogram::default();
        for v in [0.5, 0.001, 2.0, 0.25] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 2.0);
        assert!((h.mean() - (0.5 + 0.001 + 2.0 + 0.25) / 4.0).abs() < 1e-15);
    }

    #[test]
    fn out_of_range_samples_use_exact_extremes() {
        let mut h = LogHistogram::default();
        h.record(0.0); // below LO → under bucket
        h.record(5e9); // above HI → over bucket
        h.record(1.0);
        assert_eq!(h.percentile(0.0), 0.0, "underflow reports exact min");
        assert_eq!(h.percentile(100.0), 5e9, "overflow reports exact max");
    }

    #[test]
    fn percentiles_match_exact_summary_within_bound() {
        // The exact-sample Summary is the oracle: for log-uniform samples
        // spanning 8 decades, every percentile must agree within the
        // documented relative-error bound.
        let mut rng = Rng::seed_from_u64(0xb008);
        for bins in [256usize, 1024] {
            let mut h = LogHistogram::new(bins);
            let mut exact = Summary::new();
            for _ in 0..20_000 {
                // log-uniform over [1e-6, 1e2)
                let u = rng.next_u64() as f64 / u64::MAX as f64;
                let v = 1e-6 * 10f64.powf(8.0 * u);
                h.record(v);
                exact.record(v);
            }
            let bound = h.rel_error_bound();
            assert!(bound > 0.0 && bound < 0.06, "bound sane: {bound}");
            for p in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
                let want = exact.percentile(p);
                let got = h.percentile(p);
                let rel = (got - want).abs() / want;
                assert!(
                    rel <= bound + 1e-12,
                    "bins {bins} p{p}: got {got}, exact {want}, rel {rel:.5} > bound {bound:.5}"
                );
            }
        }
    }

    #[test]
    fn memory_is_fixed_regardless_of_volume() {
        let mut h = LogHistogram::new(512);
        let before = h.tracked_bytes();
        for i in 0..100_000u64 {
            h.record(1e-6 * (1 + i % 997) as f64);
        }
        assert_eq!(h.tracked_bytes(), before, "no growth with record volume");
        assert!(before < 8 * 1024, "512 bins stay in a few KiB: {before}");
    }
}
