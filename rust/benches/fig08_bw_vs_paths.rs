//! Fig 8: bandwidth vs number of relay paths.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig8_bw_vs_paths;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 8: bandwidth vs number of relay paths ===");
    let t = fig8_bw_vs_paths(fast);
    t.print();
}
