//! TTFT under a co-running bulk model wake, QoS transfer classes off vs
//! on (weighted max-min fabric + class-aware engine issue order).
//!
//! `--fast` (or `MMA_FAST_BENCH=1`) shrinks the run for smoke checks;
//! `--seed N` pins the arrival jitter.

use mma::figures::{qos_isolation, DEFAULT_SEED};
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let seed = args.seed_or(DEFAULT_SEED);
    println!("=== QoS isolation: serving TTFT vs a co-running model wake ===");
    let t = qos_isolation(fast, seed);
    t.print();
}
