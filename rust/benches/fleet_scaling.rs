//! TTFT vs fleet size with peer-NVLink prefix fetches on/off, on the
//! multi-GPU serving fleet (Poisson arrivals, one SimWorld clock).
//!
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs;
//! `--seed N` pins the arrival/workload generator.

use mma::figures::{fleet_scaling, DEFAULT_SEED};
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let seed = args.seed_or(DEFAULT_SEED);
    println!("=== Fleet scaling: TTFT vs fleet size, peer-NVLink fetch on/off ===");
    let t = fleet_scaling(fast, seed);
    t.print();
}
