//! TTFT vs offered load per transfer policy, on the event-driven serving
//! engine (Poisson arrivals, contending KV fetches).
//!
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs;
//! `--seed N` pins the arrival/workload generator.

use mma::figures::{serve_concurrency, DEFAULT_SEED};
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let seed = args.seed_or(DEFAULT_SEED);
    println!("=== Serving concurrency: TTFT vs offered load per policy ===");
    let t = serve_concurrency(fast, seed);
    t.print();
}
