//! Fig 2: proportion of prefix-cache fetching time in TTFT.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig2_ttft_share;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 2: proportion of prefix-cache fetching time in TTFT ===");
    let t = fig2_ttft_share(fast);
    t.print();
}
