//! Fig 2: proportion of prefix-cache fetching time in TTFT.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs;
//! `--seed N` pins the workload generator.

use mma::figures::{fig2_ttft_share, DEFAULT_SEED};
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let seed = args.seed_or(DEFAULT_SEED);
    println!("=== Fig 2: proportion of prefix-cache fetching time in TTFT ===");
    let t = fig2_ttft_share(fast, seed);
    t.print();
}
