//! Hot-path micro-benchmarks (§Perf, EXPERIMENTS.md): the simulator and
//! engine inner loops that bound how fast the figure harnesses run, plus
//! end-to-end transfer simulations per paper table, plus the shared
//! `mma::perf` harness whose JSON feeds `BENCH_0006_hotpath.json`
//! (see docs/PERF.md). `mma bench hotpath` runs the same harness.
//!
//! Criterion is unavailable offline; this uses `mma::util::bench`.

use mma::fabric::{max_min_rates, Fabric};
use mma::mma::{MmaConfig, SimWorld, TransferDesc};
use mma::sim::Time;
use mma::topology::{h20x8, Direction, GpuId, LinkId, NumaId};
use mma::util::bench::{black_box, Bencher};
use std::time::Duration;

fn main() {
    let mut b = Bencher::new(Duration::from_millis(150), Duration::from_millis(700));
    println!("== hot paths ==");

    // Max-min fair allocation at fleet scale (the fabric's inner loop).
    let topo = h20x8();
    let paths_owned: Vec<mma::util::SmallPath> = (0..32)
        .map(|i| {
            let g = GpuId((i % 8) as u8);
            if i % 2 == 0 {
                topo.h2d_direct(NumaId(0), g)
            } else {
                topo.h2d_relay_stage2(g, GpuId(0))
            }
        })
        .collect();
    let caps: Vec<f64> = topo.links.iter().map(|l| l.capacity_bps).collect();
    b.bench("maxmin_rates_32flows", || {
        let paths: Vec<&[LinkId]> = paths_owned.iter().map(|p| p.as_slice()).collect();
        black_box(max_min_rates(&caps, &paths));
    });

    // Fabric start/poll cycle.
    b.bench("fabric_flow_cycle", || {
        let mut f = Fabric::new(&topo);
        let path = topo.h2d_direct(NumaId(0), GpuId(0));
        for i in 0..16 {
            f.start_flow(Time::ZERO, &path, 5_000_000, Time::ZERO, i);
        }
        black_box(mma::fabric::run_to_completion(&mut f, Time::ZERO));
    });

    // Full MMA transfer simulation, 1 GB (what every figure cell costs).
    b.bench("simworld_mma_1gb_h2d", || {
        let mut w = SimWorld::new(h20x8(), MmaConfig::default());
        let s = w.stream(GpuId(0));
        let t = w.memcpy_async(s, TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), 1 << 30));
        black_box(w.run_until_transfer(t));
    });

    // Native transfer simulation (baseline cell cost).
    b.bench("simworld_native_1gb_h2d", || {
        let mut w = SimWorld::new(h20x8(), MmaConfig::native());
        let s = w.stream(GpuId(0));
        let t = w.memcpy_async(s, TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), 1 << 30));
        black_box(w.run_until_transfer(t));
    });

    // 8 GB sweep point — the most expensive single figure cell.
    b.bench("simworld_mma_8gb_h2d", || {
        let mut w = SimWorld::new(h20x8(), MmaConfig::default());
        let s = w.stream(GpuId(0));
        let t = w.memcpy_async(s, TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), 8 << 30));
        black_box(w.run_until_transfer(t));
    });

    // The shared hotpath harness (same code path as `mma bench hotpath`):
    // queue churn wheel-vs-heap, fabric flow events/s, and the twin
    // incremental/reference replay legs with their allocator counters.
    println!("\n== mma::perf::run_hotpath ==");
    print!("{}", mma::perf::run_hotpath(false).render());

    // The BENCH_0007 engine leg: the allocation-free engine pipeline in
    // isolation (chunks/s, sink growth policing; docs/PERF.md).
    println!("\n== mma::perf::run_engine_bench ==");
    print!("{}", mma::perf::run_engine_bench(false).render());

    // The BENCH_0008 serving leg: LRU prefix-tier churn, the streaming
    // histogram, and the bounded-window streamed replay vs its oracle.
    println!("\n== mma::perf::run_serving_bench ==");
    print!("{}", mma::perf::run_serving_bench(false).render());

    // The BENCH_0009 fabric leg: chunked churn through the O(due) event
    // loop — solve coalescing, lazy due heaps, interned paths.
    println!("\n== mma::perf::run_fabric_bench ==");
    print!("{}", mma::perf::run_fabric_bench(false).render());

    // The BENCH_0010 batching leg: roofline-priced fused steps with the
    // memory-wall and legacy-oracle identity bars.
    println!("\n== mma::perf::run_batching_bench ==");
    print!("{}", mma::perf::run_batching_bench(false).render());
}
