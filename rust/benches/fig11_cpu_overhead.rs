//! Fig 11: CPU cores consumed by MMA vs relay GPUs.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig11_cpu_overhead;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 11: CPU cores consumed by MMA vs relay GPUs ===");
    let t = fig11_cpu_overhead();
    t.print();
}
