//! Fig 3: proportion of transfer time in swap-in/out latency.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig3_swap_share;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 3: proportion of transfer time in swap-in/out latency ===");
    let t = fig3_swap_share();
    t.print();
}
