//! Fig 15: chunk size / outstanding-queue-depth sensitivity.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig15_sensitivity;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 15: chunk size / outstanding-queue-depth sensitivity ===");
    let t = fig15_sensitivity(fast);
    t.print();
}
