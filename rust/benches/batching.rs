//! TTFT / TPOT vs batch size × prefill chunk × context under the
//! batch-aware H20 roofline — the continuous-batching memory-wall sweep
//! (decode step time grows with aggregate KV bytes, prefill stays
//! roughly flat per token). Same table as `mma figure batching`.
//!
//! `--fast` (or `MMA_FAST_BENCH`) shrinks the sweep for smoke runs; the
//! sweep is deterministic (all arrivals at t=0), so there is no seed.

use mma::figures::batching;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    println!("=== Continuous batching: TTFT/TPOT vs batch x chunk x context ===");
    let t = batching(fast);
    t.print();
}
