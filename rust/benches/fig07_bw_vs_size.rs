//! Fig 7: H2D/D2H bandwidth vs transfer size, MMA vs native.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig7_bw_vs_size;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 7: H2D/D2H bandwidth vs transfer size, MMA vs native ===");
    let t = fig7_bw_vs_size(fast);
    t.print();
}
