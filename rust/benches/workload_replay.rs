//! Trace-driven workload replay: TTFT mean/p99, prefix-hit rate, and
//! PCIe utilization per arrival shape (Poisson vs MMPP bursts at equal
//! mean rate) × transfer policy × QoS.
//!
//! `--fast` (or `MMA_FAST_BENCH=1`) shrinks the run for smoke checks;
//! `--seed N` pins the trace generation.

use mma::figures::{workload_replay, DEFAULT_SEED};
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let seed = args.seed_or(DEFAULT_SEED);
    println!("=== Workload replay: TTFT vs arrival burstiness x policy x QoS ===");
    let t = workload_replay(fast, seed);
    t.print();
}
