//! Fig 13: fall-asleep / wake-up latency baseline vs MMA.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig13_switching;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 13: fall-asleep / wake-up latency baseline vs MMA ===");
    let t = fig13_switching();
    t.print();
}
