//! Fig 10: completion time vs static splits, +/- background.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig10_static_split;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 10: completion time vs static splits, +/- background ===");
    let t = fig10_static_split();
    t.print();
}
