//! Fig 14: MMA bandwidth vs relay count under TP configs.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig14_tp_sweep;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 14: MMA bandwidth vs relay count under TP configs ===");
    let t = fig14_tp_sweep();
    t.print();
}
