//! Fig 12: TTFT baseline vs MMA across models and contexts.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs;
//! `--seed N` pins the workload generator.

use mma::figures::{fig12_ttft, DEFAULT_SEED};
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let seed = args.seed_or(DEFAULT_SEED);
    println!("=== Fig 12: TTFT baseline vs MMA across models and contexts ===");
    let t = fig12_ttft(fast, seed);
    t.print();
}
