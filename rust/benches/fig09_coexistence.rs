//! Fig 9: bandwidth under congestion (MMA+native, MMA+MMA).
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig9_coexistence;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 9: bandwidth under congestion (MMA+native, MMA+MMA) ===");
    let t = fig9_coexistence();
    t.print();
}
