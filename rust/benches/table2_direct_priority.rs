//! Table 2: influence of direct priority on P2P bandwidth.
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::table2_direct_priority;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Table 2: influence of direct priority on P2P bandwidth ===");
    let t = table2_direct_priority();
    t.print();
}
