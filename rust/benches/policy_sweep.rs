//! Policy sweep: every transfer policy on the Fig-8 bandwidth-vs-paths
//! workload — native, static-split, mma-greedy, congestion-feedback and
//! numa-aware through the identical engine/measurement path.
//!
//! `--fast` (or `cargo bench -- --fast`) shrinks the transfer size.

use mma::figures::policy_sweep;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    println!("=== Policy sweep: H2D bandwidth vs relay paths, per policy ===");
    let t = policy_sweep(fast);
    t.print();
}
