//! Fig 16: optimal fallback threshold (MMA vs native break-even).
//!
//! Regenerates the paper's rows on the simulated 8xH20 testbed.
//! `--fast` (or `cargo bench -- --fast`) shrinks the sweep for smoke runs.

use mma::figures::fig16_fallback;
use mma::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let fast = args.flag("fast") || std::env::var("MMA_FAST_BENCH").is_ok();
    let _ = fast;
    println!("=== Fig 16: optimal fallback threshold (MMA vs native break-even) ===");
    let t = fig16_fallback();
    t.print();
}
