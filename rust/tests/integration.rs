//! Cross-module integration tests: interceptor → engine → fabric → gpusim
//! under realistic serving scenarios, plus determinism and failure cases.

use mma::config::{FleetConfig, RunConfig, ServingConfig};
use mma::figures::workload_replay::{replay, replay_serving, ReplayOptions};
use mma::mma::{MmaConfig, SimWorld, TransferClass, TransferDesc};
use mma::models::{qwen3_4b, qwen_7b_chat};
use mma::workload::Trace;
use mma::policy::PolicySpec;
use mma::serving::{
    ModelRegistry, ModelState, Request, RequestId, RoutePolicy, ServingEngine, ServingFleet,
};
use mma::sim::Time;
use mma::topology::{h20x8, single_numa_4gpu, Direction, GpuId, NumaId};

use mma::testkit::{fixed, h2d};

#[test]
fn simulation_is_deterministic() {
    let run = || {
        let mut w = SimWorld::new(h20x8(), MmaConfig::default());
        let s0 = w.stream(GpuId(0));
        let s3 = w.stream(GpuId(3));
        let a = w.memcpy_async(s0, h2d(0, 700_000_000));
        let b = w.memcpy_async(s3, h2d(3, 300_000_000));
        w.run_until_idle();
        (
            w.rec(a).completed.unwrap().ns(),
            w.rec(b).completed.unwrap().ns(),
            w.rec(a).bytes_relay,
            w.rec(b).bytes_relay,
        )
    };
    assert_eq!(run(), run(), "same inputs must give bit-exact results");
}

#[test]
fn concurrent_transfers_to_all_gpus_complete() {
    let mut w = SimWorld::new(h20x8(), MmaConfig::default());
    let mut ids = Vec::new();
    for g in 0..8u8 {
        let s = w.stream(GpuId(g));
        let numa = w.topo.numa_of(GpuId(g));
        ids.push(w.memcpy_async(
            s,
            TransferDesc::new(Direction::H2D, GpuId(g), numa, 500_000_000),
        ));
    }
    w.run_until_idle();
    for id in ids {
        let rec = w.rec(id);
        assert!(rec.completed.is_some(), "{id:?} never completed");
        assert_eq!(rec.bytes_direct + rec.bytes_relay, 500_000_000);
        // With every GPU busy on its own transfer, direct priority keeps
        // most bytes on the direct path (Table 2's mechanism).
        assert!(
            rec.direct_fraction() > 0.5,
            "{id:?} relayed too much: {}",
            rec.direct_fraction()
        );
    }
}

#[test]
fn mixed_directions_share_the_fabric() {
    let mut w = SimWorld::new(h20x8(), MmaConfig::default());
    let s0 = w.stream(GpuId(0));
    let s1 = w.stream(GpuId(1));
    let up = w.memcpy_async(s0, h2d(0, 1 << 30));
    let down = w.memcpy_async(s1, TransferDesc::new(Direction::D2H, GpuId(1), NumaId(0), 1 << 30));
    w.run_until_idle();
    // PCIe is full duplex: concurrent H2D and D2H barely interfere.
    let bw_up = w.rec(up).bandwidth().unwrap();
    let bw_down = w.rec(down).bandwidth().unwrap();
    assert!(bw_up > 150e9, "H2D degraded: {bw_up}");
    assert!(bw_down > 120e9, "D2H degraded: {bw_down}");
}

#[test]
fn single_numa_preset_runs_mma() {
    let topo = single_numa_4gpu();
    let mut w = SimWorld::new(topo, MmaConfig::default());
    let s = w.stream(GpuId(0));
    let t = w.memcpy_async(s, h2d(0, 1 << 30));
    w.run_until_transfer(t);
    let bw = w.rec(t).bandwidth().unwrap();
    // 4 paths, no xGMI anywhere: ~switch-limited ≈ 180-200 GB/s (§6).
    assert!((150e9..220e9).contains(&bw), "single-numa bw {bw}");
}

#[test]
fn static_split_mode_end_to_end() {
    let cfg = mma::policy::split_1_1(GpuId(0), GpuId(1));
    let mut w = SimWorld::new(h20x8(), cfg);
    let s = w.stream(GpuId(0));
    let t = w.memcpy_async(s, h2d(0, 512 << 20));
    w.run_until_transfer(t);
    let rec = w.rec(t);
    // 1:1 split: half the bytes relayed (chunk-rounding slack allowed).
    let frac = rec.direct_fraction();
    assert!((0.4..0.6).contains(&frac), "1:1 split fraction {frac}");
}

#[test]
fn config_file_drives_the_world() {
    let cfg = RunConfig::from_toml(
        r#"
        [run]
        preset = "h20x8"
        [mma]
        mode = "mma"
        chunk_bytes = 2_000_000
        relay_gpus = [1]
        "#,
    )
    .unwrap();
    let mut w = SimWorld::new(cfg.topology(), cfg.mma.clone());
    let s = w.stream(GpuId(0));
    let t = w.memcpy_async(s, h2d(0, 512 << 20));
    w.run_until_transfer(t);
    let bw = w.rec(t).bandwidth().unwrap();
    // Exactly two paths (direct + gpu1) sharing one PCIe switch uplink.
    assert!((90e9..110e9).contains(&bw), "two-path bw {bw}");
}

#[test]
fn serving_registry_over_shared_world() {
    // A registry sleep/wake storm while a KV fetch runs: everything shares
    // one fabric and still completes.
    let mut w = SimWorld::new(h20x8(), MmaConfig::default());
    let mut reg = ModelRegistry::new(NumaId(0));
    let m = reg.register(qwen3_4b(), vec![GpuId(2)]);
    let s = w.stream(GpuId(0));
    let fetch = w.memcpy_async(s, h2d(0, qwen_7b_chat().kv_bytes(16_384)));
    let slept = reg.sleep(&mut w, m);
    assert_eq!(reg.instance(m).state, ModelState::Asleep);
    w.run_until_transfer(fetch);
    assert!(slept.transfer > Time::ZERO);
    let woke = reg.wake(&mut w, m);
    assert!(woke.transfer > Time::ZERO);
    w.run_until_idle();
}

#[test]
fn backpressure_shifts_work_off_contended_path() {
    // Pin gpu1's PCIe lane with background traffic; MMA must route around
    // it: gpu1 relays fewer bytes than an uncontended peer behind the
    // other switch.
    let mut w = SimWorld::new(h20x8(), MmaConfig::default());
    let bg_path = w.topo.h2d_direct(NumaId(0), GpuId(1));
    w.start_bg_loop(bg_path, 512 << 20, 30, TransferClass::Bulk);
    let s = w.stream(GpuId(0));
    w.memcpy_async(s, h2d(0, 4 << 30));
    w.run_until_idle();
    let stats = &w.engine(0, Direction::H2D).stats;
    let relayed_g1 = stats.bytes_by_path[1];
    let relayed_g2 = stats.bytes_by_path[2];
    assert!(
        relayed_g1 < relayed_g2,
        "contended path must carry less: g1={relayed_g1} g2={relayed_g2}"
    );
}

#[test]
fn fallback_and_engine_routes_coexist_on_one_stream() {
    let mut w = SimWorld::new(h20x8(), MmaConfig::default());
    let s = w.stream(GpuId(0));
    let small = w.memcpy_async(s, h2d(0, 1_000_000)); // fallback
    let large = w.memcpy_async(s, h2d(0, 200_000_000)); // engine
    let small2 = w.memcpy_async(s, h2d(0, 2_000_000)); // fallback again
    w.run_until_idle();
    // Stream FIFO: small completes before large starts, etc.
    let t1 = w.rec(small).completed.unwrap();
    let a2 = w.rec(large).activated.unwrap();
    let t2 = w.rec(large).released.unwrap();
    let a3 = w.rec(small2).activated.unwrap();
    assert!(t1 <= a2, "large copy started before the small one finished");
    assert!(t2 <= a3, "stream order violated after dummy task");
    assert_eq!(w.rec(small).bytes_relay, 0);
    assert!(w.rec(large).bytes_relay > 0);
}

#[test]
fn centralized_dispatch_mode_works() {
    let cfg = MmaConfig {
        centralized_dispatch: true,
        ..Default::default()
    };
    let mut w = SimWorld::new(h20x8(), cfg);
    let s = w.stream(GpuId(0));
    let t = w.memcpy_async(s, h2d(0, 1 << 30));
    w.run_until_transfer(t);
    let bw = w.rec(t).bandwidth().unwrap();
    // Slightly below per-GPU mode (one dispatcher serializes harder), but
    // still multipath-fast.
    assert!(bw > 180e9, "centralized bw {bw}");
}

#[test]
fn policy_matrix_all_complete() {
    // Property-style matrix: every policy/direction/size combination must
    // complete with conserved bytes through the one shared engine path.
    let policies = [
        PolicySpec::Native,
        PolicySpec::MmaGreedy,
        PolicySpec::Static(vec![(GpuId(5), 1.0), (GpuId(4), 1.0)]),
        PolicySpec::congestion_feedback(),
        PolicySpec::numa_aware(),
    ];
    for policy in &policies {
        for dir in [Direction::H2D, Direction::D2H] {
            for bytes in [1_000u64, 5_000_000, 123_456_789] {
                let cfg = MmaConfig {
                    policy: policy.clone(),
                    ..Default::default()
                };
                let mut w = SimWorld::new(h20x8(), cfg);
                let s = w.stream(GpuId(5));
                let numa = w.topo.numa_of(GpuId(5));
                let t = w.memcpy_async(s, TransferDesc::new(dir, GpuId(5), numa, bytes));
                w.run_until_idle();
                let rec = w.rec(t);
                assert!(rec.completed.is_some(), "{policy:?}/{dir:?}/{bytes}");
                assert_eq!(
                    rec.bytes_direct + rec.bytes_relay,
                    bytes,
                    "{policy:?}/{dir:?}/{bytes}: bytes not conserved"
                );
            }
        }
    }
}

#[test]
fn policy_config_section_drives_the_world() {
    // A [policy] section selects the adaptive policy end-to-end; the run
    // completes and reports the policy's name through the serving surface.
    let cfg = RunConfig::from_toml(
        r#"
        [policy]
        name = "congestion-feedback"
        ewma_alpha = 0.5
        "#,
    )
    .unwrap();
    let mut w = SimWorld::new(cfg.topology(), cfg.mma.clone());
    assert_eq!(w.policy_name(), "congestion-feedback");
    let s = w.stream(GpuId(0));
    let t = w.memcpy_async(s, h2d(0, 1 << 30));
    w.run_until_transfer(t);
    let rec = w.rec(t);
    // Adaptive multipath on a clean fabric: far beyond single-path rate.
    assert!(rec.bandwidth().unwrap() > 150e9);
    assert!(rec.bytes_relay > 0);
}

#[test]
fn numa_aware_policy_profile_differs_from_greedy() {
    // 60 MB = 12 chunks: by the time the numa1 workers wake (FIFO wake
    // order), the remaining backlog sits below the 32 MB remote threshold,
    // so the numa-aware policy keeps the tail on-socket while greedy
    // recruits both sockets.
    let bytes = 60_000_000u64;
    let relay_share_numa1 = |policy: PolicySpec| {
        let cfg = MmaConfig {
            policy,
            ..Default::default()
        };
        let mut w = SimWorld::new(h20x8(), cfg);
        let s = w.stream(GpuId(0));
        let t = w.memcpy_async(s, h2d(0, bytes));
        w.run_until_transfer(t);
        let stats = &w.engine(0, Direction::H2D).stats;
        (4..8).map(|g| stats.bytes_by_path[g]).sum::<u64>()
    };
    let greedy = relay_share_numa1(PolicySpec::MmaGreedy);
    let numa = relay_share_numa1(PolicySpec::numa_aware());
    assert_eq!(numa, 0, "numa-aware must keep a small transfer on-socket");
    assert!(greedy > 0, "greedy should have recruited the remote socket");
}

// ----- event-driven serving layer ------------------------------------

fn serving_engine(cfg: ServingConfig, mma: MmaConfig, prefill_s: f64) -> ServingEngine {
    mma::testkit::engine(cfg, mma, fixed(prefill_s, 0.001))
}

fn hit_request(id: u64, ctx: u32, key: u64) -> Request {
    mma::testkit::hit(id, 0, ctx, key)
}

#[test]
fn concurrent_host_fetches_contend_in_the_fabric() {
    // Two concurrent requests' host-tier KV fetches share gpu0's direct
    // PCIe path under the native policy: each must run slower than a solo
    // fetch (max-min sharing), while aggregate bytes are conserved.
    let ctx = 16_384u32;
    let solo = {
        let mut e = serving_engine(ServingConfig::default(), MmaConfig::native(), 0.05);
        e.seed_host_prefix(1, ctx);
        let out = e.run(vec![hit_request(1, ctx, 1)]);
        out[0].ttft.fetch_s
    };
    let mut e = serving_engine(ServingConfig::default(), MmaConfig::native(), 0.05);
    e.seed_host_prefix(1, ctx);
    e.seed_host_prefix(2, ctx);
    let out = e.run(vec![hit_request(1, ctx, 1), hit_request(2, ctx, 2)]);
    for o in &out {
        assert!(
            o.ttft.fetch_s > 1.5 * solo,
            "contended fetch {} vs solo {solo}",
            o.ttft.fetch_s
        );
        assert!(
            o.ttft.fetch_s < 2.5 * solo,
            "fair sharing bound: {} vs solo {solo}",
            o.ttft.fetch_s
        );
    }
    // Byte conservation across every transfer the run submitted.
    let fetch_bytes = qwen_7b_chat().kv_bytes(ctx as u64);
    let mut fetched = 0u64;
    for rec in &e.world().transfers {
        assert!(rec.completed.is_some(), "{:?} incomplete", rec.id);
        assert_eq!(
            rec.bytes_direct + rec.bytes_relay,
            rec.desc.bytes,
            "{:?}: bytes not conserved",
            rec.id
        );
        if rec.desc.bytes == fetch_bytes {
            fetched += rec.desc.bytes;
        }
    }
    assert_eq!(fetched, 2 * fetch_bytes, "both fetches moved in full");
}

#[test]
fn overlapped_fetch_and_prefill_beat_the_serialized_sum() {
    // Request A is a cold prefill; request B is a host-tier hit. Event-
    // driven serving overlaps B's fetch with A's compute, so B's TTFT is
    // well below the serialized sum the old lock-step engine would pay.
    let mut e = serving_engine(ServingConfig::default(), MmaConfig::native(), 0.3);
    e.seed_host_prefix(9, 65_536);
    let cold = Request {
        id: RequestId(1),
        arrival: Time::ZERO,
        prompt_tokens: 8000,
        cached_prefix_tokens: 0,
        prefix_key: 0,
        output_tokens: 2,
        tenant: 0,
        class: None,
    };
    let out = e.run(vec![cold, hit_request(2, 65_536, 9)]);
    let (a, b) = (&out[0], &out[1]);
    assert!(b.ttft.fetch_s > 0.2, "B must fetch from host: {}", b.ttft.fetch_s);
    let serialized = a.ttft.prefill_s + b.ttft.fetch_s + b.ttft.prefill_s;
    assert!(
        b.ttft_s() < 0.8 * serialized,
        "overlap must beat serialization: {} vs {serialized}",
        b.ttft_s()
    );
}

#[test]
fn chunked_fetch_overlaps_within_one_request() {
    // fetch_chunks > 1: prefill compute starts after the first chunk
    // lands, so a single request's TTFT drops below fetch + prefill.
    let cfg = ServingConfig {
        fetch_chunks: 8,
        ..Default::default()
    };
    let mut e = serving_engine(cfg, MmaConfig::native(), 0.2);
    e.seed_host_prefix(3, 65_536);
    let out = e.run(vec![hit_request(1, 65_536, 3)]);
    let o = &out[0];
    assert!(
        o.ttft_s() < 0.9 * (o.ttft.fetch_s + o.ttft.prefill_s),
        "pipelined ttft {} vs serialized {}",
        o.ttft_s(),
        o.ttft.fetch_s + o.ttft.prefill_s
    );
}

#[test]
fn model_wake_coruns_with_serving_traffic() {
    // A registry wake-up targeting the serving GPU shares its direct PCIe
    // path with a live KV fetch: both complete on the one event loop, and
    // the fetch visibly slows versus an idle fabric (the end-to-end
    // generalization of the Fig 9 coexistence scenario).
    let ctx = 16_384u32;
    let solo = {
        let mut e = serving_engine(ServingConfig::default(), MmaConfig::native(), 0.05);
        e.seed_host_prefix(1, ctx);
        e.run(vec![hit_request(1, ctx, 1)])[0].ttft.fetch_s
    };
    let mut e = serving_engine(ServingConfig::default(), MmaConfig::native(), 0.05);
    let mut reg = ModelRegistry::new(NumaId(0));
    let m = reg.register(qwen3_4b(), vec![GpuId(0)]);
    reg.sleep(e.world_mut(), m);
    e.seed_host_prefix(1, ctx);
    let arrival = e.world().now();
    let wake = reg.start_wake(e.world_mut(), m);
    let out = e.run(vec![Request {
        arrival,
        ..hit_request(1, ctx, 1)
    }]);
    assert_eq!(reg.instance(m).state, ModelState::Active);
    let phase = wake.wait(e.world_mut());
    assert!(phase.transfer > Time::ZERO);
    assert!(
        out[0].ttft.fetch_s > 1.3 * solo,
        "wake traffic must slow the fetch: {} vs solo {solo}",
        out[0].ttft.fetch_s
    );
}

#[test]
fn qos_shields_serving_fetch_from_corunning_wake() {
    // The same wake-co-run scenario, with the multipath engine on both
    // sides: the 32B wake (Bulk) multipaths across every PCIe lane,
    // trampling the serving fetch (LatencyCritical) when QoS is off.
    // With `[qos]` enabled the fetch holds its weighted share of every
    // shared link and issues first in the engine queues, so its TTFT
    // fetch component must strictly improve — while the wake still lands.
    let ctx = 16_384u32;
    let run = |qos_on: bool| {
        let mut mcfg = MmaConfig::default();
        mcfg.qos.enabled = qos_on;
        let mut e = serving_engine(ServingConfig::default(), mcfg, 0.05);
        let mut reg = ModelRegistry::new(NumaId(1));
        let m = reg.register(mma::models::qwen3_32b(), vec![GpuId(4)]);
        reg.sleep(e.world_mut(), m);
        e.seed_host_prefix(1, ctx);
        let arrival = e.world().now();
        let wake = reg.start_wake(e.world_mut(), m);
        let out = e.run(vec![Request {
            arrival,
            ..hit_request(1, ctx, 1)
        }]);
        let phase = wake.wait(e.world_mut());
        (out[0].ttft.fetch_s, phase.transfer.as_secs_f64())
    };
    let (fetch_off, wake_off) = run(false);
    let (fetch_on, wake_on) = run(true);
    assert!(
        fetch_on < fetch_off,
        "QoS must shield the fetch: on {fetch_on} vs off {fetch_off}"
    );
    assert!(wake_on > 0.0 && wake_off > 0.0, "wake completes either way");
    assert!(
        wake_on < 5.0 * wake_off,
        "wake may only degrade modestly: on {wake_on} vs off {wake_off}"
    );
}

// ----- multi-GPU serving fleet ---------------------------------------

fn serving_fleet(gpus: u32, peer_fetch: bool, mma: MmaConfig, prefill_s: f64) -> ServingFleet {
    mma::testkit::fleet(gpus, peer_fetch, mma, prefill_s)
}

#[test]
fn peer_nvlink_hit_beats_host_fetch_under_pcie_contention() {
    // Background DMA pins gpu1's PCIe lane. Request 1 promotes the shared
    // prefix into gpu0's HBM; request 2 lands on instance 1 (round-robin)
    // and needs the same prefix. With peer fetching on, the KV rides the
    // idle NVLink fabric; with it off, it squeezes through the contended
    // PCIe lane — the fleet-level version of the paper's multipath claim.
    let ctx = 32_768u32;
    let run = |peer: bool| {
        let mut f = serving_fleet(2, peer, MmaConfig::native(), 0.05);
        let bg_path = f.world.topo.h2d_direct(NumaId(0), GpuId(1));
        f.world.start_bg_loop(bg_path, 512 << 20, 500, TransferClass::Bulk);
        f.seed_host_prefix(7, ctx);
        let out = f.run(vec![
            hit_request(1, ctx, 7),
            Request {
                arrival: Time::from_ms(5000),
                ..hit_request(2, ctx, 7)
            },
        ]);
        assert_eq!(f.assignment(RequestId(1)), Some(0));
        assert_eq!(f.assignment(RequestId(2)), Some(1));
        out[1].ttft.fetch_s
    };
    let contended_host = run(false);
    let peer_nvlink = run(true);
    // 32k tokens ≈ 8.5 GB: ~0.16 s on an idle lane, ~0.31 s sharing it.
    assert!(
        contended_host > 0.25,
        "bg traffic must slow the host fetch: {contended_host}"
    );
    assert!(
        peer_nvlink < 0.2 * contended_host,
        "peer-NVLink hit {peer_nvlink} vs contended host-PCIe fetch {contended_host}"
    );
}

#[test]
fn fleet_instances_contend_only_where_paths_overlap() {
    // Two instances fetching distinct prefixes use distinct PCIe lanes:
    // neither pays the ~2x contention penalty a single shared lane shows
    // (contrast with `concurrent_host_fetches_contend_in_the_fabric`).
    let ctx = 16_384u32;
    let solo = {
        let mut e = serving_engine(ServingConfig::default(), MmaConfig::native(), 0.05);
        e.seed_host_prefix(1, ctx);
        e.run(vec![hit_request(1, ctx, 1)])[0].ttft.fetch_s
    };
    let mut f = serving_fleet(2, false, MmaConfig::native(), 0.05);
    f.seed_host_prefix(1, ctx);
    f.seed_host_prefix(2, ctx);
    let out = f.run(vec![hit_request(1, ctx, 1), hit_request(2, ctx, 2)]);
    for o in &out {
        assert!(
            o.ttft.fetch_s < 1.2 * solo,
            "separate lanes must not serialize: {} vs solo {solo}",
            o.ttft.fetch_s
        );
    }
}

#[test]
fn fleet_config_section_drives_serve_end_to_end() {
    // A [fleet] TOML section builds a working fleet: requests complete,
    // placement honors the configured router, peer fetches occur.
    let cfg = RunConfig::from_toml(
        r#"
        [fleet]
        gpus = 2
        router = "round-robin"
        peer_fetch = true
        "#,
    )
    .unwrap();
    assert_eq!(cfg.fleet.gpus, 2);
    assert_eq!(cfg.fleet.router, RoutePolicy::RoundRobin);
    let serving = ServingConfig {
        pd_disaggregation: false,
        ..cfg.serving.clone()
    };
    let computes = mma::testkit::fixed_computes(2, 0.05, 0.001);
    let world = SimWorld::new(cfg.topology(), cfg.mma.clone());
    let mut f = ServingFleet::new(
        cfg.fleet.clone(),
        serving,
        qwen_7b_chat(),
        world,
        computes,
        NumaId(0),
    );
    f.seed_host_prefix(3, 16_384);
    let out = f.run(vec![
        hit_request(1, 16_384, 3),
        Request {
            arrival: Time::from_ms(2000),
            ..hit_request(2, 16_384, 3)
        },
    ]);
    assert!(out.iter().all(|o| o.finished_at.is_some()));
    let (host, peer) = f.fetch_counts();
    assert_eq!((host, peer), (1, 1), "second turn rides NVLink");
}

#[test]
fn sample_trace_parses_and_replays_deterministically() {
    // The shipped example trace is the CI smoke input: it must parse,
    // round-trip through the canonical rendering, and replay to
    // byte-identical metrics on repeated runs (the replay acceptance
    // gate), including its tenant-namespaced warm prefixes.
    let text = include_str!("../../examples/sample_trace.jsonl");
    let trace = Trace::parse(text).expect("sample trace parses");
    assert_eq!(trace.records.len(), 12);
    assert_eq!(Trace::parse(&trace.render()).unwrap(), trace);
    // Tenant 2's document arrives warm on its first turn → pre-seeded.
    assert!(trace
        .warm_prefixes()
        .iter()
        .any(|&(tenant, key, _)| tenant == 2 && key == 201));
    let fleet = FleetConfig {
        gpus: 2,
        router: RoutePolicy::RoundRobin,
        peer_fetch: true,
        prefix_affinity: false,
    };
    let run = || {
        replay(
            &trace,
            &qwen_7b_chat(),
            MmaConfig::default(),
            replay_serving(),
            fleet.clone(),
            &ReplayOptions::default(),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(
        a.render(),
        b.render(),
        "same trace + config must print byte-identical metrics"
    );
    assert_eq!(a.requests, 12);
    assert!(a.prefix_hits > 0, "warm turns must hit the prefix tiers");
    assert!(a.makespan_s > 0.0);
    // Per-tenant grouping covers every tenant in the trace.
    let tenants: Vec<u32> = a.per_tenant.iter().map(|(t, _, _)| *t).collect();
    assert_eq!(tenants, vec![1, 2, 3]);
}

#[test]
fn trace_replay_honors_fleet_and_policy_dimensions() {
    // A generated bursty trace replayed under two policies: both
    // complete all requests, fetch accounting responds to the peer
    // switch, and the [workload]-driven generator is seed-stable.
    use mma::util::rng::Rng;
    use mma::workload::{ArrivalProcess, TenantSpec, TraceGen};
    let mut a = TenantSpec::interactive(1, 3, 8_192);
    a.warm_start = true; // previous-session documents → host-tier fetches
    let mut b = TenantSpec::interactive(2, 3, 8_192);
    b.warm_start = true;
    let gen = TraceGen {
        arrivals: ArrivalProcess::bursty(16.0, 0.8, 1.5),
        tenants: vec![a, b],
        requests: 24,
    };
    let trace = gen.generate(&mut Rng::seed_from_u64(0xF16));
    assert_eq!(trace, gen.generate(&mut Rng::seed_from_u64(0xF16)));
    let run = |peer: bool| {
        let fleet = FleetConfig {
            gpus: 2,
            router: RoutePolicy::RoundRobin,
            peer_fetch: peer,
            prefix_affinity: false,
        };
        replay(
            &trace,
            &qwen_7b_chat(),
            MmaConfig::native(),
            replay_serving(),
            fleet,
            &ReplayOptions::default(),
        )
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.requests, 24);
    assert_eq!(off.peer_fetches, 0, "no NVLink fetches with the switch off");
    assert!(
        on.peer_fetches > 0,
        "round-robined repeat hits must ride NVLink when on"
    );
    assert!(on.host_fetches < off.host_fetches);
}
