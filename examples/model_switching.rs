//! Model switching under vLLM-style Sleep Mode (§5.2.2), with a router
//! waking models on demand.
//!
//! ```text
//! cargo run --release --example model_switching -- --mode mma
//! cargo run --release --example model_switching -- --mode native
//! ```
//!
//! Two models share gpu0; requests alternate between them, so every
//! switch pays a fall-asleep (D2H) + wake-up (H2D) weight move. MMA cuts
//! both phases by recruiting the seven idle peer GPUs as relays.

use mma::mma::{MmaConfig, SimWorld};
use mma::models::{qwen3_32b, qwen_7b_chat};
use mma::serving::router::Policy;
use mma::serving::{ModelRegistry, Router};
use mma::topology::{h20x8, GpuId, NumaId};
use mma::util::cli::Args;
use mma::util::fmt;

fn run(mode: &str) -> (f64, f64) {
    let cfg = if mode == "native" {
        MmaConfig::native()
    } else {
        MmaConfig::default()
    };
    let mut world = SimWorld::new(h20x8(), cfg);
    let mut reg = ModelRegistry::new(NumaId(0));
    let a = reg.register(qwen_7b_chat(), vec![GpuId(0)]);
    let b = reg.register(qwen3_32b(), vec![GpuId(0)]);
    // Only one fits on the GPU at a time: B starts asleep.
    let sleep_b = reg.sleep(&mut world, b);
    println!(
        "  [{mode}] initial: {} asleep (took {})",
        reg.instance(b).spec.name,
        fmt::secs(sleep_b.total().as_secs_f64())
    );

    let mut router = Router::new(Policy::RoundRobin, 2);
    let mut total_switch = 0.0;
    let mut switches = 0u32;
    // Alternate requests A, B, A, B: every one triggers a switch.
    for turn in 0..4 {
        let want = if turn % 2 == 0 { b } else { a };
        // Sleep the other model first (single-GPU residency).
        let other = if want == a { b } else { a };
        if reg.instance(other).state == mma::serving::ModelState::Active {
            let s = reg.sleep(&mut world, other);
            total_switch += s.total().as_secs_f64();
        }
        let (inst, wake) = router.route(&mut world, &mut reg, &[want]);
        if let Some(wcost) = wake {
            total_switch += wcost.as_secs_f64();
            switches += 1;
            println!(
                "  [{mode}] request {turn} -> {} woken in {}",
                reg.instance(inst).spec.name,
                fmt::secs(wcost.as_secs_f64())
            );
        }
        router.done(inst);
    }
    (total_switch, switches as f64)
}

fn main() {
    let args = Args::from_env();
    let only = args.get("mode").map(str::to_string);
    println!("model switching (sleep/wake) on simulated 8xH20:\n");
    let mut results = Vec::new();
    for mode in ["native", "mma"] {
        if let Some(m) = &only {
            if m != mode {
                continue;
            }
        }
        let (total, n) = run(mode);
        println!(
            "  [{mode}] {} switches, total switch latency {}\n",
            n,
            fmt::secs(total)
        );
        results.push((mode, total));
    }
    if results.len() == 2 {
        println!(
            "switch-latency speedup (native/MMA): {:.2}x (paper: 1.12-2.48x)",
            results[0].1 / results[1].1
        );
    }
}
