//! End-to-end driver: serve a real (tiny) model through the full
//! three-layer stack, with KV-cache offload/fetch accelerated by MMA.
//!
//! ```text
//! make artifacts   # once: JAX+Pallas -> HLO text
//! cargo run --release --example kv_offload_serving
//! ```
//!
//! Phase A — **live serving**: loads `artifacts/tiny_{prefill,decode}.hlo.txt`
//! (lowered from the L2 JAX model calling the L1 Pallas attention kernels),
//! compiles them on the PJRT CPU client, and serves batched requests with
//! real prefill + token-by-token decode. KV pages are offloaded to the
//! simulated host tier between turns and fetched back on prefix hits; the
//! fetch travels the simulated fabric (MMA vs native), compute is real.
//!
//! Phase B — **paper-scale shadow**: the same serving path at Qwen-7B-Chat
//! KV volumes (roofline compute), reproducing the Fig 12 TTFT comparison.

use mma::metrics::Summary;
use mma::mma::{MmaConfig, SimWorld, TransferDesc};
use mma::models::{qwen_7b_chat, tiny_serve};
use mma::runtime::{artifacts_dir, lit, PjrtRuntime};
use mma::topology::{h20x8, Direction, GpuId, NumaId};
use mma::util::cli::Args;
use mma::util::fmt;
use std::time::Instant;

const PREFILL_LEN: usize = 32;
const VOCAB: i32 = 1024;

struct LiveServer {
    rt: PjrtRuntime,
    world: SimWorld,
    spec: mma::models::ModelSpec,
}

struct Served {
    ttft_fetch_s: f64,
    ttft_prefill_s: f64,
    tokens: Vec<i32>,
    decode_s: f64,
}

impl LiveServer {
    fn new(mma_cfg: MmaConfig) -> anyhow::Result<LiveServer> {
        let mut rt = PjrtRuntime::cpu()?;
        let loaded = rt.load_dir(&artifacts_dir())?;
        anyhow::ensure!(
            loaded.iter().any(|n| n == "tiny_prefill") && loaded.iter().any(|n| n == "tiny_decode"),
            "artifacts missing; run `make artifacts` first (found {loaded:?})"
        );
        Ok(LiveServer {
            rt,
            world: SimWorld::new(h20x8(), mma_cfg),
            spec: tiny_serve(),
        })
    }

    /// Serve one request: optional host-tier KV fetch (simulated fabric),
    /// real prefill, then `gen` real decode steps.
    fn serve(&mut self, prompt: &[i32], prefix_hit: bool, gen: usize) -> anyhow::Result<Served> {
        // 1. KV fetch on a prefix hit: the pages live in pinned host memory
        //    (offloaded after the previous turn) and must be fetched to the
        //    GPU before decode — the paper's latency-critical path.
        let mut fetch_s = 0.0;
        if prefix_hit {
            let bytes = self.spec.kv_bytes(PREFILL_LEN as u64).max(1);
            let t0 = self.world.now();
            let t = self.world.memcpy_sync(TransferDesc::new(
                Direction::H2D,
                GpuId(0),
                NumaId(0),
                bytes,
            ));
            let done = self.world.run_until_transfer(t);
            fetch_s = done.since(t0).as_secs_f64();
        }

        // 2. Real prefill through PJRT (L2 model + L1 Pallas kernels).
        let wall = Instant::now();
        let out = self
            .rt
            .execute("tiny_prefill", &[lit::i32(prompt, &[1, PREFILL_LEN as i64])?])?;
        let prefill_s = wall.elapsed().as_secs_f64();
        let (logits, mut k, mut v) = (lit::to_f32(&out[0])?, out[1].clone(), out[2].clone());
        let mut next = argmax(&logits[(PREFILL_LEN - 1) * VOCAB as usize..]);

        // 3. Real decode loop.
        let wall = Instant::now();
        let mut tokens = Vec::with_capacity(gen);
        for step in 0..gen {
            tokens.push(next);
            let pos = (PREFILL_LEN + step) as i32;
            let out = self.rt.execute(
                "tiny_decode",
                &[
                    lit::i32(&[next], &[1])?,
                    k.clone(),
                    v.clone(),
                    lit::i32(&[pos], &[1])?,
                ],
            )?;
            next = argmax(&lit::to_f32(&out[0])?);
            k = out[1].clone();
            v = out[2].clone();
        }
        let decode_s = wall.elapsed().as_secs_f64();

        // 4. Offload KV back to the host tier (D2H over the fabric).
        let bytes = self.spec.kv_bytes((PREFILL_LEN + gen) as u64).max(1);
        let t = self
            .world
            .memcpy_sync(TransferDesc::new(Direction::D2H, GpuId(0), NumaId(0), bytes));
        self.world.run_until_transfer(t);

        Ok(Served {
            ttft_fetch_s: fetch_s,
            ttft_prefill_s: prefill_s,
            tokens,
            decode_s,
        })
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0;
    for (i, x) in xs.iter().enumerate() {
        if *x > xs[best] {
            best = i;
        }
    }
    best as i32
}

fn phase_a(requests: usize, gen: usize) -> anyhow::Result<()> {
    println!("== Phase A: live serving (real tiny model via JAX->Pallas->HLO->PJRT) ==");
    let mut srv = LiveServer::new(MmaConfig::default())?;
    println!("   PJRT platform: {}", srv.rt.platform());
    let mut ttft = Summary::new();
    let mut tpot = Summary::new();
    let mut total_tokens = 0usize;
    let wall = Instant::now();
    let mut last_tokens: Vec<i32> = Vec::new();
    for r in 0..requests {
        let prompt: Vec<i32> = (0..PREFILL_LEN as i32).map(|i| (i * 13 + r as i32) % VOCAB).collect();
        let hit = r > 0 && r % 2 == 0; // every other request reuses a prefix
        let out = srv.serve(&prompt, hit, gen)?;
        ttft.record(out.ttft_fetch_s + out.ttft_prefill_s);
        tpot.record(out.decode_s / gen as f64);
        total_tokens += out.tokens.len();
        last_tokens = out.tokens;
    }
    let elapsed = wall.elapsed().as_secs_f64();
    println!(
        "   {} requests x {gen} tokens: mean TTFT {} (p99 {}), mean TPOT {}, throughput {:.1} tok/s",
        requests,
        fmt::secs(ttft.mean()),
        fmt::secs(ttft.p99()),
        fmt::secs(tpot.mean()),
        total_tokens as f64 / elapsed,
    );
    println!("   sample generation: {last_tokens:?}");
    Ok(())
}

fn phase_b(ctx: u32) {
    println!("\n== Phase B: paper-scale KV fetch (Qwen-7B-Chat @ {}k ctx, Fig 12 regime) ==", ctx / 1024);
    let spec = qwen_7b_chat();
    let bytes = spec.kv_bytes(ctx as u64);
    for mode in ["native", "mma"] {
        let cfg = if mode == "native" { MmaConfig::native() } else { MmaConfig::default() };
        let mut w = SimWorld::new(h20x8(), cfg);
        let t = w.memcpy_sync(TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), bytes));
        let done = w.run_until_transfer(t);
        let fetch = done.as_secs_f64();
        let prefill = mma::roofline::h20().prefill_secs(&spec, 256, ctx as u64, 1);
        println!(
            "   {mode:>6}: fetch {} of {} + suffix prefill {} -> TTFT {} ({:.0}% fetch)",
            fmt::secs(fetch),
            fmt::bytes(bytes),
            fmt::secs(prefill),
            fmt::secs(fetch + prefill),
            100.0 * fetch / (fetch + prefill)
        );
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let requests: usize = args.or("requests", 6);
    let gen: usize = args.or("gen", 8);
    phase_a(requests, gen)?;
    phase_b(args.or("ctx", 65_536));
    Ok(())
}
