//! Quickstart: expand one host→GPU copy across multipath relays.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the simulated 8×H20 server, issues the same 1 GB `cudaMemcpyAsync`
//! under native CUDA semantics and under MMA, and prints what happened —
//! including the Dummy-Task lifecycle that keeps CUDA stream ordering
//! intact (§3.2/§3.3 of the paper).

use mma::mma::{MmaConfig, SimWorld, TransferDesc};
use mma::sim::Time;
use mma::topology::{h20x8, Direction, GpuId, NumaId};
use mma::util::fmt;

fn main() {
    let bytes: u64 = 1 << 30;

    // --- native baseline: the copy is bound to gpu0's PCIe lane ---------
    let mut w = SimWorld::new(h20x8(), MmaConfig::native());
    let s = w.stream(GpuId(0));
    let t = w.memcpy_async(s, TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), bytes));
    w.run_until_transfer(t);
    let native = w.rec(t).bandwidth().unwrap();
    println!("native  : {} in {} -> {}", fmt::bytes(bytes),
        fmt::secs(w.rec(t).completed.unwrap().as_secs_f64()), fmt::gbps(native));

    // --- MMA: same API call, now intercepted --------------------------
    let mut w = SimWorld::new(h20x8(), MmaConfig::default());
    let s = w.stream(GpuId(0));
    let t = w.memcpy_async(s, TransferDesc::new(Direction::H2D, GpuId(0), NumaId(0), bytes));
    // A downstream kernel depends on the copy — the spin-kernel Dummy Task
    // must hold it back until every micro-task lands.
    w.enqueue_kernel(s, Time::from_us(50), "consumer");
    w.run_until_idle();
    let rec = w.rec(t);
    let mma = rec.bandwidth().unwrap();
    println!(
        "MMA     : {} in {} -> {}  ({:.2}x)",
        fmt::bytes(bytes),
        fmt::secs(rec.completed.unwrap().as_secs_f64()),
        fmt::gbps(mma),
        mma / native
    );
    println!(
        "          direct path {} | relayed via peers {} ({:.0}% relayed)",
        fmt::bytes(rec.bytes_direct),
        fmt::bytes(rec.bytes_relay),
        100.0 * (1.0 - rec.direct_fraction())
    );
    println!(
        "          copy point active at {}, payload landed at {}, stream released at {}",
        fmt::secs(rec.activated.unwrap().as_secs_f64()),
        fmt::secs(rec.completed.unwrap().as_secs_f64()),
        fmt::secs(rec.released.unwrap().as_secs_f64()),
    );
    assert!(rec.released.unwrap() > rec.completed.unwrap());
    println!("\nstream semantics preserved: consumer kernel ran only after release.");
}
