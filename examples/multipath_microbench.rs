//! Microbenchmark: host<->GPU bandwidth, MMA vs native CUDA copies.
//!
//! Reproduces the paper's §5.1.1 measurement methodology on the simulated
//! 8xH20 server: pinned buffers, timed transfers, effective bandwidth =
//! size / completion time. Sweeps message size for a given relay count:
//!
//! ```text
//! cargo run --release --example multipath_microbench -- --relays 7
//! ```

use mma::mma::{MmaConfig, SimWorld, TransferDesc};
use mma::topology::{h20x8, Direction, GpuId, NumaId};
use mma::util::{cli::Args, table::Table};

fn measure(dir: Direction, bytes: u64, cfg: MmaConfig) -> f64 {
    let mut w = SimWorld::new(h20x8(), cfg);
    let s = w.stream(GpuId(0));
    let t = w.memcpy_async(s, TransferDesc::new(dir, GpuId(0), NumaId(0), bytes));
    w.run_until_transfer(t);
    w.rec(t).bandwidth().unwrap_or(0.0)
}

fn main() {
    let args = Args::from_env();
    let relays: usize = args.or("relays", 7);
    let topo = h20x8();
    let relay_set: Vec<GpuId> = topo
        .relay_order(GpuId(0), &[])
        .into_iter()
        .take(relays)
        .collect();

    let sizes: &[u64] = &[
        1 << 10,
        64 << 10,
        1 << 20,
        5 << 20,
        10 << 20,
        50 << 20,
        100 << 20,
        512 << 20,
        1 << 30,
        4u64 << 30,
        8u64 << 30,
    ];

    for dir in [Direction::H2D, Direction::D2H] {
        let mut t = Table::new(["size", "native GB/s", "MMA GB/s", "speedup"]);
        for &b in sizes {
            let native = measure(dir, b, MmaConfig::native());
            let mma_cfg = MmaConfig {
                relay_gpus: Some(relay_set.clone()),
                ..MmaConfig::default()
            };
            let m = measure(dir, b, mma_cfg);
            t.row([
                mma::util::fmt::bytes(b),
                format!("{:.1}", native / 1e9),
                format!("{:.1}", m / 1e9),
                format!("{:.2}x", m / native),
            ]);
        }
        println!(
            "\n=== {} bandwidth vs transfer size ({} relays) ===",
            dir.label(),
            relays
        );
        t.print();
    }
}
