"""L1 correctness gate: Pallas attention kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (block-aligned lengths, head dims, seeds) and
asserts allclose in float32. These run before any artifact is exported
(`make test` and the artifacts rule both depend on them passing).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    DEFAULT_BLOCK_K,
    mha_decode,
    mha_decode_batched,
    mha_prefill,
    mha_prefill_batched,
)
from compile.kernels.ref import (
    attn_decode_ref,
    attn_prefill_batched_ref,
    attn_prefill_ref,
)

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def qkv(seed, t, s, d):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return rand(k1, (t, d)), rand(k2, (s, d)), rand(k3, (s, d))


class TestPrefillKernel:
    def test_matches_ref_basic(self):
        q, k, v = qkv(0, 32, 32, 64)
        np.testing.assert_allclose(mha_prefill(q, k, v), attn_prefill_ref(q, k, v), **TOL)

    def test_multi_block_kv(self):
        q, k, v = qkv(1, 64, 64, 64)
        np.testing.assert_allclose(mha_prefill(q, k, v), attn_prefill_ref(q, k, v), **TOL)

    def test_first_row_attends_only_itself(self):
        q, k, v = qkv(2, 32, 32, 64)
        out = mha_prefill(q, k, v)
        np.testing.assert_allclose(out[0], v[0], **TOL)

    def test_rejects_misaligned_kv(self):
        q, k, v = qkv(3, 32, 33, 64)
        with pytest.raises(AssertionError):
            mha_prefill(q, k, v)

    @settings(max_examples=20, deadline=None)
    @given(
        blocks=st.integers(1, 4),
        d=st.sampled_from([32, 64, 128]),
        block_k=st.sampled_from([16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, blocks, d, block_k, seed):
        t = blocks * block_k
        q, k, v = qkv(seed, t, t, d)
        out = mha_prefill(q, k, v, block_k=block_k)
        np.testing.assert_allclose(out, attn_prefill_ref(q, k, v), **TOL)


class TestDecodeKernel:
    def test_matches_ref_basic(self):
        q, k, v = qkv(0, 1, 128, 64)
        mask = (jnp.arange(128) < 40).astype(jnp.float32)
        np.testing.assert_allclose(
            mha_decode(q, k, v, mask), attn_decode_ref(q, k, v, mask), **TOL
        )

    def test_single_valid_position_returns_that_value(self):
        q, k, v = qkv(1, 1, 64, 32)
        mask = jnp.zeros(64).at[7].set(1.0)
        out = mha_decode(q, k, v, mask)
        np.testing.assert_allclose(out[0], v[7], **TOL)

    def test_mask_excludes_padding(self):
        q, k, v = qkv(2, 1, 128, 64)
        mask = (jnp.arange(128) < 50).astype(jnp.float32)
        base = mha_decode(q, k, v, mask)
        # Corrupting masked-out rows must not change the result.
        v2 = v.at[50:].set(1e6)
        k2 = k.at[50:].set(-1e6)
        out = mha_decode(q, k2, v2, mask)
        np.testing.assert_allclose(out, base, **TOL)

    @settings(max_examples=20, deadline=None)
    @given(
        blocks=st.integers(1, 4),
        d=st.sampled_from([32, 64]),
        valid_frac=st.floats(0.05, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_mask_sweep(self, blocks, d, valid_frac, seed):
        s = blocks * DEFAULT_BLOCK_K
        q, k, v = qkv(seed, 1, s, d)
        valid = max(1, int(s * valid_frac))
        mask = (jnp.arange(s) < valid).astype(jnp.float32)
        out = mha_decode(q, k, v, mask)
        np.testing.assert_allclose(out, attn_decode_ref(q, k, v, mask), **TOL)


class TestBatchedWrappers:
    def test_prefill_batched_matches_ref(self):
        key = jax.random.PRNGKey(9)
        k1, k2, k3 = jax.random.split(key, 3)
        b, t, h, d = 2, 32, 4, 64
        q = rand(k1, (b, t, h, d))
        k = rand(k2, (b, t, h, d))
        v = rand(k3, (b, t, h, d))
        np.testing.assert_allclose(
            mha_prefill_batched(q, k, v), attn_prefill_batched_ref(q, k, v), **TOL
        )

    def test_decode_batched_matches_per_head(self):
        key = jax.random.PRNGKey(11)
        k1, k2, k3 = jax.random.split(key, 3)
        b, s, h, d = 1, 64, 4, 32
        q = rand(k1, (b, h, d))
        kc = rand(k2, (b, s, h, d))
        vc = rand(k3, (b, s, h, d))
        mask = (jnp.arange(s) < 20).astype(jnp.float32)[None, :]
        out = mha_decode_batched(q, kc, vc, mask)
        assert out.shape == (b, h, d)
        for hh in range(h):
            ref = attn_decode_ref(q[0, hh : hh + 1], kc[0, :, hh], vc[0, :, hh], mask[0])
            np.testing.assert_allclose(out[0, hh], ref[0], **TOL)
