"""L2 gate: tiny-model semantics — shapes, cache layout, and the key
invariant that step-by-step decode reproduces prefill logits."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import TINY, decode, init_weights, prefill


@pytest.fixture(scope="module")
def weights():
    return init_weights(TINY, seed=0)


@pytest.fixture(scope="module")
def tokens():
    return (jnp.arange(TINY.prefill_len, dtype=jnp.int32)[None, :] * 13 + 7) % TINY.vocab


def test_prefill_shapes(weights, tokens):
    logits, k, v = prefill(tokens, weights)
    assert logits.shape == (1, TINY.prefill_len, TINY.vocab)
    assert k.shape == (TINY.layers, 1, TINY.max_len, TINY.heads, TINY.head_dim)
    assert v.shape == k.shape
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_cache_padding_is_zero(weights, tokens):
    _, k, v = prefill(tokens, weights)
    assert float(jnp.abs(k[:, :, TINY.prefill_len :]).max()) == 0.0
    assert float(jnp.abs(v[:, :, TINY.prefill_len :]).max()) == 0.0


def test_decode_shapes_and_cache_update(weights, tokens):
    _, k, v = prefill(tokens, weights)
    pos = jnp.array([TINY.prefill_len], jnp.int32)
    logits, k2, v2 = decode(jnp.array([5], jnp.int32), k, v, pos, weights)
    assert logits.shape == (1, TINY.vocab)
    # Cache row at `pos` must now be populated, earlier rows unchanged.
    assert float(jnp.abs(k2[:, :, TINY.prefill_len]).max()) > 0.0
    np.testing.assert_array_equal(k2[:, :, : TINY.prefill_len], k[:, :, : TINY.prefill_len])


def test_decode_reproduces_prefill_logits(weights, tokens):
    """Feeding the prompt token-by-token through decode must match the
    prefill logits at every position (same math, two code paths — this is
    the strongest end-to-end check of kernels + cache plumbing)."""
    full_logits, _, _ = prefill(tokens, weights)
    L, H, D = TINY.layers, TINY.heads, TINY.head_dim
    k = jnp.zeros((L, 1, TINY.max_len, H, D), jnp.float32)
    v = jnp.zeros_like(k)
    for i in range(8):  # first 8 positions are plenty (and fast)
        tok = tokens[0, i : i + 1]
        logits, k, v = decode(tok, k, v, jnp.array([i], jnp.int32), weights)
        np.testing.assert_allclose(
            logits[0], full_logits[0, i], rtol=5e-4, atol=5e-4
        )


def test_different_prompts_give_different_logits(weights):
    t1 = jnp.zeros((1, TINY.prefill_len), jnp.int32)
    t2 = jnp.ones((1, TINY.prefill_len), jnp.int32)
    l1, _, _ = prefill(t1, weights)
    l2, _, _ = prefill(t2, weights)
    assert float(jnp.abs(l1 - l2).max()) > 1e-3


def test_weights_deterministic():
    a = init_weights(TINY, seed=0)
    b = init_weights(TINY, seed=0)
    np.testing.assert_array_equal(a["embed"], b["embed"])
    c = init_weights(TINY, seed=1)
    assert float(jnp.abs(a["embed"] - c["embed"]).max()) > 0.0
