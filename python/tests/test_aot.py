"""AOT export gate: HLO text is parseable-by-old-XLA and self-contained."""

import pytest

from compile.aot import lower_all


@pytest.fixture(scope="module")
def artifacts():
    return lower_all()


def test_exports_both_entry_points(artifacts):
    assert set(artifacts) == {"tiny_prefill", "tiny_decode"}


def test_no_elided_constants(artifacts):
    # The default printer writes `constant({...})`, silently zeroing the
    # baked weights on the Rust side. Guard against regressions.
    for name, text in artifacts.items():
        assert "{...}" not in text, f"{name} has elided constants"


def test_no_new_metadata_attributes(artifacts):
    # xla_extension 0.5.1's parser rejects source_end_line etc.
    for name, text in artifacts.items():
        assert "source_end_line" not in text, f"{name} has new metadata"


def test_weights_are_baked(artifacts):
    # ~4.5M f32 parameters make the text tens of MB; a tiny file means the
    # constants went missing.
    assert len(artifacts["tiny_prefill"]) > 10_000_000
    assert len(artifacts["tiny_decode"]) > 10_000_000
