"""L1: Pallas fused attention kernels (the serving compute hot-spot).

Two kernels, both flash-attention style with online softmax and the KV
axis blocked through ``BlockSpec`` (the TPU analogue of the paper's GPU
tiling: HBM->VMEM staging expressed as a block schedule instead of
threadblocks; MXU-shaped matmuls instead of WMMA; VMEM accumulators
carried across sequential grid steps instead of shared memory):

* :func:`mha_prefill` — causal self-attention over a full prompt.
* :func:`mha_decode`  — one query row against a padded KV cache with a
  validity mask (decode step).

``interpret=True`` everywhere: the CPU PJRT runtime cannot execute Mosaic
custom-calls, and correctness is what the build-time pytest gate checks
(see ``python/tests/test_kernel.py`` against ``ref.py``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default KV-axis block (rows staged into VMEM per grid step).
DEFAULT_BLOCK_K = 32

NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, block_k: int):
    """One (q-block, kv-block) step of causal flash attention.

    Grid: (num_kv_blocks,). The q block is resident across all steps; the
    online-softmax state (m: running max, l: running denominator) and the
    weighted accumulator o are carried in output refs, which interpret/TPU
    grids visit sequentially.
    """
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]  # [T, D]
    k = k_ref[...]  # [block_k, D]
    v = v_ref[...]  # [block_k, D]

    # MXU-shaped matmul in fp32 accumulation.
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [T, block_k]
    s = s / jnp.sqrt(jnp.float32(q.shape[-1]))

    # Causal mask: query row i attends to kv col (j*block_k + jj) <= i.
    t = q.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, block_k), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, block_k), 1) + j * block_k
    s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_ref[...]  # [T, 1]
    l_prev = l_ref[...]  # [T, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # Rescale previous accumulator, fold in this block.
    p = jnp.exp(s - m_new)  # [T, block_k]
    alpha = jnp.exp(m_prev - m_new)  # [T, 1]
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new


def mha_prefill(q, k, v, *, block_k: int = DEFAULT_BLOCK_K):
    """Causal attention for one head: q,k,v ``[T, D]`` -> ``[T, D]``.

    ``T`` must be a multiple of ``block_k`` (the model pads prompts to the
    artifact's fixed prefill length).
    """
    t, d = q.shape
    s = k.shape[0]
    assert s % block_k == 0, f"kv length {s} % block_k {block_k} != 0"
    grid = (s // block_k,)
    o, m, l = pl.pallas_call(
        functools.partial(_prefill_kernel, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, d), lambda j: (0, 0)),  # q resident
            pl.BlockSpec((block_k, d), lambda j: (j, 0)),  # kv streamed
            pl.BlockSpec((block_k, d), lambda j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t, d), lambda j: (0, 0)),
            pl.BlockSpec((t, 1), lambda j: (0, 0)),
            pl.BlockSpec((t, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), jnp.float32),
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return (o / l).astype(q.dtype)


def _decode_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref):
    """One kv-block step of single-row attention with a validity mask."""
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...]  # [1, D]
    k = k_ref[...]  # [block_k, D]
    v = v_ref[...]
    mask = mask_ref[...]  # [1, block_k] 1.0 = valid

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [1, block_k]
    s = s / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.where(mask > 0.5, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = o_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new


def mha_decode(q, k, v, mask, *, block_k: int = DEFAULT_BLOCK_K):
    """Decode attention for one head.

    q ``[1, D]``; k,v ``[S, D]`` (padded cache); mask ``[S]`` with 1.0 on
    valid positions. Returns ``[1, D]``.
    """
    _, d = q.shape
    s = k.shape[0]
    assert s % block_k == 0, f"kv length {s} % block_k {block_k} != 0"
    grid = (s // block_k,)
    mask2 = mask.reshape(1, s).astype(jnp.float32)
    o, m, l = pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda j: (0, 0)),
            pl.BlockSpec((block_k, d), lambda j: (j, 0)),
            pl.BlockSpec((block_k, d), lambda j: (j, 0)),
            pl.BlockSpec((1, block_k), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, mask2)
    return (o / l).astype(q.dtype)


def mha_prefill_batched(q, k, v, *, block_k: int = DEFAULT_BLOCK_K):
    """Causal attention over ``[B, T, H, D]`` via vmap over batch x heads."""
    per_head = functools.partial(mha_prefill, block_k=block_k)
    # [B, H, T, D]
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = jax.vmap(jax.vmap(per_head))(qt, kt, vt)
    return jnp.swapaxes(out, 1, 2)


def mha_decode_batched(q, k, v, mask, *, block_k: int = DEFAULT_BLOCK_K):
    """Decode attention over ``[B, H, D]`` vs caches ``[B, S, H, D]``."""
    per_head = functools.partial(mha_decode, block_k=block_k)

    def one_batch(qb, kb, vb, maskb):
        # qb [H, D], kb [S, H, D]
        qh = qb[:, None, :]  # [H, 1, D]
        kh = jnp.swapaxes(kb, 0, 1)  # [H, S, D]
        vh = jnp.swapaxes(vb, 0, 1)
        out = jax.vmap(lambda a, b, c: per_head(a, b, c, maskb))(qh, kh, vh)
        return out[:, 0, :]  # [H, D]

    return jax.vmap(one_batch)(q, k, v, mask)
