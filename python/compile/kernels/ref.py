"""Pure-jnp correctness oracles for the Pallas attention kernels.

Straight softmax attention with explicit masks — no blocking, no online
softmax. The pytest gate asserts the Pallas kernels match these within
float32 tolerance before anything is AOT-exported.
"""

import jax.numpy as jnp


def attn_prefill_ref(q, k, v):
    """Causal attention, one head: q,k,v ``[T, D]`` -> ``[T, D]``."""
    t = q.shape[0]
    s = jnp.einsum("td,sd->ts", q, k) / jnp.sqrt(jnp.float32(q.shape[-1]))
    causal = jnp.tril(jnp.ones((t, k.shape[0]), dtype=bool), k=0)
    s = jnp.where(causal, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return (p @ v).astype(q.dtype)


def attn_decode_ref(q, k, v, mask):
    """Masked single-row attention: q ``[1, D]``, k/v ``[S, D]``, mask ``[S]``."""
    s = jnp.einsum("td,sd->ts", q, k) / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.where(mask[None, :] > 0.5, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return (p @ v).astype(q.dtype)


def attn_prefill_batched_ref(q, k, v):
    """Causal attention over ``[B, T, H, D]``."""
    s = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(jnp.float32(q.shape[-1]))
    t, sl = q.shape[1], k.shape[1]
    causal = jnp.tril(jnp.ones((t, sl), dtype=bool), k=0)
    s = jnp.where(causal[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhts,bshd->bthd", p, v).astype(q.dtype)
