"""AOT export: lower the L2 model (prefill + decode) to HLO *text*.

HLO text — NOT ``lowered.compile()`` output or ``.serialize()`` protos —
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the Rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage::

    cd python && python -m compile.aot --outdir ../artifacts

Writes ``tiny_prefill.hlo.txt``, ``tiny_decode.hlo.txt`` and
``tiny_meta.json`` (shape metadata for the Rust runtime).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import TINY, decode, init_weights, prefill


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text.

    ``print_large_constants=True`` is essential: the default printer elides
    big literals as ``constant({...})``, which silently zeroes the baked
    model weights on the Rust side.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New-jax metadata attributes (source_end_line etc.) are rejected by
    # xla_extension 0.5.1's parser; strip metadata entirely.
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def lower_all(cfg=TINY, seed: int = 0):
    """Lower prefill and decode with weights baked in. Returns dict name->text."""
    w = init_weights(cfg, seed)

    def prefill_fn(tokens):
        return prefill(tokens, weights=w, cfg=cfg)

    def decode_fn(token, k_cache, v_cache, pos):
        return decode(token, k_cache, v_cache, pos, weights=w, cfg=cfg)

    tok_spec = jax.ShapeDtypeStruct((1, cfg.prefill_len), jnp.int32)
    cache_spec = jax.ShapeDtypeStruct(
        (cfg.layers, 1, cfg.max_len, cfg.heads, cfg.head_dim), jnp.float32
    )
    one = jax.ShapeDtypeStruct((1,), jnp.int32)

    return {
        "tiny_prefill": to_hlo_text(jax.jit(prefill_fn).lower(tok_spec)),
        "tiny_decode": to_hlo_text(
            jax.jit(decode_fn).lower(one, cache_spec, cache_spec, one)
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file alias")
    args = ap.parse_args()
    outdir = args.outdir
    if args.out:  # legacy Makefile interface: put files beside --out
        outdir = os.path.dirname(args.out) or "."
    os.makedirs(outdir, exist_ok=True)

    arts = lower_all()
    for name, text in arts.items():
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars to {path}")

    meta = {
        "config": {
            "vocab": TINY.vocab,
            "hidden": TINY.hidden,
            "layers": TINY.layers,
            "heads": TINY.heads,
            "head_dim": TINY.head_dim,
            "intermediate": TINY.intermediate,
            "prefill_len": TINY.prefill_len,
            "max_len": TINY.max_len,
        },
        "artifacts": sorted(arts.keys()),
    }
    with open(os.path.join(outdir, "tiny_meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote metadata to {outdir}/tiny_meta.json")


if __name__ == "__main__":
    main()
