"""L2: the tiny decoder-only transformer served by the Rust runtime.

Architecture must match ``rust/src/models/mod.rs::tiny_serve()``: 4 layers,
hidden 256, 4 heads x head_dim 64, FFN 1024, vocab 1024. Weights are
generated from a fixed seed and closed over as constants, so the lowered
HLO is fully self-contained — the Rust side feeds tokens, gets logits and
KV caches back, and Python never runs at serving time.

Two entry points, both calling the L1 Pallas kernels:

* :func:`prefill` — tokens ``[1, PREFILL_LEN]`` -> (logits, k_cache, v_cache)
* :func:`decode`  — (token ``[1]``, k_cache, v_cache, pos ``[1]``) ->
  (logits, k_cache, v_cache)

Caches are ``[LAYERS, 1, MAX_LEN, HEADS, HEAD_DIM]`` padded to MAX_LEN.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import mha_decode_batched, mha_prefill_batched


@dataclass(frozen=True)
class TinyConfig:
    vocab: int = 1024
    hidden: int = 256
    layers: int = 4
    heads: int = 4
    head_dim: int = 64
    intermediate: int = 1024
    prefill_len: int = 32
    max_len: int = 128


TINY = TinyConfig()


def init_weights(cfg: TinyConfig = TINY, seed: int = 0):
    """Deterministic weight pytree (baked into the HLO as constants)."""
    key = jax.random.PRNGKey(seed)
    n_keys = 2 + cfg.layers * 7
    keys = iter(jax.random.split(key, n_keys))

    def mat(k, shape):
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(
            jnp.float32(shape[0])
        )

    w = {
        "embed": jax.random.normal(next(keys), (cfg.vocab, cfg.hidden), jnp.float32)
        * 0.02,
        "layers": [],
    }
    qd = cfg.heads * cfg.head_dim
    for _ in range(cfg.layers):
        w["layers"].append(
            {
                "wq": mat(next(keys), (cfg.hidden, qd)),
                "wk": mat(next(keys), (cfg.hidden, qd)),
                "wv": mat(next(keys), (cfg.hidden, qd)),
                "wo": mat(next(keys), (qd, cfg.hidden)),
                "wg": mat(next(keys), (cfg.hidden, cfg.intermediate)),
                "wu": mat(next(keys), (cfg.hidden, cfg.intermediate)),
                "wd": mat(next(keys), (cfg.intermediate, cfg.hidden)),
            }
        )
    w["norm_final"] = jnp.ones((cfg.hidden,), jnp.float32)
    return w


def rmsnorm(x):
    """RMS layer norm (no learned scale except the final one)."""
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _mlp(layer, x):
    return (jax.nn.silu(x @ layer["wg"]) * (x @ layer["wu"])) @ layer["wd"]


def prefill(tokens, weights=None, cfg: TinyConfig = TINY):
    """Full-prompt forward. tokens ``[1, prefill_len]`` int32.

    Returns (logits ``[1, T, vocab]``, k_cache, v_cache) with caches padded
    to ``cfg.max_len``.
    """
    w = weights if weights is not None else init_weights(cfg)
    b, t = tokens.shape
    x = w["embed"][tokens]  # [B, T, H]
    ks, vs = [], []
    for layer in w["layers"]:
        h = rmsnorm(x)
        q = (h @ layer["wq"]).reshape(b, t, cfg.heads, cfg.head_dim)
        k = (h @ layer["wk"]).reshape(b, t, cfg.heads, cfg.head_dim)
        v = (h @ layer["wv"]).reshape(b, t, cfg.heads, cfg.head_dim)
        # L1 Pallas kernel: causal flash attention.
        att = mha_prefill_batched(q, k, v)
        x = x + att.reshape(b, t, -1) @ layer["wo"]
        x = x + _mlp(layer, rmsnorm(x))
        pad = cfg.max_len - t
        ks.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))))
        vs.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))
    logits = rmsnorm(x * w["norm_final"]) @ w["embed"].T
    return logits, jnp.stack(ks), jnp.stack(vs)


def decode(token, k_cache, v_cache, pos, weights=None, cfg: TinyConfig = TINY):
    """One decode step.

    token ``[1]`` int32; caches ``[L, 1, max_len, H, D]``; pos ``[1]`` int32
    (number of tokens already in the cache). Returns (logits ``[1, vocab]``,
    k_cache, v_cache) with the new token written at ``pos``.
    """
    w = weights if weights is not None else init_weights(cfg)
    p = pos[0]
    x = w["embed"][token][:, None, :]  # [1, 1, H]
    mask = (jnp.arange(cfg.max_len) <= p).astype(jnp.float32)[None, :]  # [1, S]
    new_k, new_v = [], []
    for li, layer in enumerate(w["layers"]):
        h = rmsnorm(x)
        q = (h @ layer["wq"]).reshape(1, cfg.heads, cfg.head_dim)
        k = (h @ layer["wk"]).reshape(1, 1, cfg.heads, cfg.head_dim)
        v = (h @ layer["wv"]).reshape(1, 1, cfg.heads, cfg.head_dim)
        kc = jax.lax.dynamic_update_slice(k_cache[li], k, (0, p, 0, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[li], v, (0, p, 0, 0))
        new_k.append(kc)
        new_v.append(vc)
        # L1 Pallas kernel: masked decode attention over the padded cache.
        att = mha_decode_batched(q, kc, vc, mask)  # [1, H, D]
        x = x + att.reshape(1, 1, -1) @ layer["wo"]
        x = x + _mlp(layer, rmsnorm(x))
    logits = (rmsnorm(x * w["norm_final"]) @ w["embed"].T)[:, 0, :]
    return logits, jnp.stack(new_k), jnp.stack(new_v)
